//! The disaggregated decision-plane service (§4.2, §5.1).
//!
//! `m` sampler workers run on dedicated threads. Each iteration, the engine
//! publishes one [`IterationTask`] per sampler over that sampler's SPSC ring
//! (the shared-memory ring analog); the task carries a zero-copy
//! [`ShardedLogits`] view plus per-column metadata. Samplers decide their
//! columns independently — **sequence-parallel**, no vocabulary-axis
//! reconciliation — and push [`DecisionBatch`]es to the shared return
//! channel (the paper's lightweight ZMQ path back to the scheduler).
//!
//! **Ownership.** A sequence is owned by sampler `seq_id % m` for its whole
//! life, so its history metadata is created, updated, and retired *locally*
//! (the paper's "per-sequence metadata follow the same batch partition and
//! are updated locally"), independent of batch composition. Ownership-by-id
//! replaces the paper's per-iteration contiguous ranges — the balance is the
//! same in expectation and history never migrates.
//!
//! **Determinism.** Decisions use pre-generated Philox uniforms keyed by
//! (engine seed, request seed, sequence, iteration), so the token stream is
//! identical for any `m` (asserted in tests).

use super::grammar::{ConstraintState, GrammarConstraint};
use super::hotvocab::HotVocab;
use super::params::SamplingParams;
use super::penalties::BatchHistory;
use super::pipeline::DecisionPipeline;
use super::shvs::Precompute;
use super::verify::{self, Verdict};
use crate::config::SamplerConfig;
#[cfg(test)]
use crate::config::DecisionVariant;
use crate::ringbuf::{mpmc, spsc};
use crate::tensor::ShardedLogits;
use std::collections::HashMap;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Per-column metadata within an iteration's microbatch.
#[derive(Debug, Clone)]
pub struct ColumnMeta {
    pub col: usize,
    pub seq_id: u64,
    /// Decode iteration of the *base* chain position for this sequence
    /// (speculative positions key their uniforms at `iteration + j`).
    pub iteration: u64,
}

/// One iteration's work for the decision plane. Shared (Arc'd) pieces are
/// written once by the engine and read zero-copy by every sampler.
///
/// Speculative decoding ships the whole draft chain in one task:
/// `views[0]` is the base decode step's logits; `views[j > 0]` were
/// produced by feeding draft token `j-1`, and `drafts[ci]` carries column
/// `ci`'s proposed window. The batch-axis sharding is untouched — each
/// sampler still reads only its owned columns, in every view, with no
/// vocab-axis collectives.
pub struct IterationTask {
    pub iter: u64,
    /// Per-chain-position logits views (len 1 = plain decode).
    pub views: Vec<ShardedLogits>,
    pub columns: Arc<Vec<ColumnMeta>>,
    /// Per-view, per-column SHVS precompute: `pre[j][col]` (empty when the
    /// variant doesn't use it).
    pub pre: Arc<Vec<Vec<Precompute>>>,
    /// Draft windows aligned with `columns` (an empty window = plain
    /// decision; an empty outer vec = no speculation this iteration).
    pub drafts: Arc<Vec<Vec<u32>>>,
}

impl IterationTask {
    /// A plain non-speculative iteration: one view, no drafts. `pre` is the
    /// per-column SHVS precompute for that view (may be empty).
    pub fn single(
        iter: u64,
        view: ShardedLogits,
        columns: Vec<ColumnMeta>,
        pre: Vec<Precompute>,
    ) -> IterationTask {
        let pre = if pre.is_empty() { Vec::new() } else { vec![pre] };
        IterationTask {
            iter,
            views: vec![view],
            columns: Arc::new(columns),
            pre: Arc::new(pre),
            drafts: Arc::new(Vec::new()),
        }
    }
}

/// Control + data messages flowing engine → sampler.
pub enum SamplerMsg {
    /// A sequence enters the system: register its prompt + params with its
    /// owner sampler. `output` is non-empty when a preempted sequence
    /// resumes (recompute-on-resume): the owner replays those tokens into
    /// its local history/grammar state so penalties and constraints are
    /// byte-identical to an uninterrupted run.
    Register {
        seq_id: u64,
        prompt: Vec<u32>,
        output: Vec<u32>,
        params: SamplingParams,
        grammar: Option<Arc<GrammarConstraint>>,
    },
    /// Decide this iteration's owned columns.
    Iterate(Arc<IterationTask>),
    /// A sequence finished: drop its metadata.
    Retire { seq_id: u64 },
}

/// One sampler's decisions for one iteration.
#[derive(Debug)]
pub struct DecisionBatch {
    pub iter: u64,
    pub sampler_id: usize,
    /// (column, seq_id, verdict) — a verdict commits 1..=k+1 tokens
    /// (accepted draft prefix + corrected bonus; exactly 1 without
    /// speculation).
    pub decisions: Vec<(usize, u64, Verdict)>,
    /// Wall seconds this sampler spent deciding (busy time).
    pub busy_s: f64,
}

/// Running service handle.
pub struct SamplerService {
    senders: Vec<spsc::Producer<SamplerMsg>>,
    results: mpmc::Receiver<DecisionBatch>,
    workers: Vec<JoinHandle<SamplerStats>>,
    m: usize,
}

/// Per-sampler lifetime statistics. (Speculative-decoding acceptance is
/// tallied engine-side from *committed* windows — see
/// `PjrtEngine::spec_accepted` — not here, where discarded-after-preemption
/// verdicts would skew the counts.)
#[derive(Debug, Clone, Default)]
pub struct SamplerStats {
    pub decisions: u64,
    pub fast_path_hits: u64,
    pub alpha_sum: f64,
    pub busy_s: f64,
}

/// A sampler's worker loop state.
struct SamplerWorker {
    id: usize,
    m: usize,
    pipeline: DecisionPipeline,
    /// Histories of owned sequences, keyed by seq_id. Each history is a
    /// single-column BatchHistory (the column-wise machinery per sequence).
    owned: HashMap<u64, OwnedSeq>,
}

/// Per-sequence sampler-local state.
struct OwnedSeq {
    hist: BatchHistory,
    params: SamplingParams,
    grammar: Option<(Arc<GrammarConstraint>, ConstraintState)>,
}

impl SamplerWorker {
    fn owns(&self, seq_id: u64) -> bool {
        (seq_id as usize) % self.m == self.id
    }

    fn run(
        mut self,
        rx: spsc::Consumer<SamplerMsg>,
        tx: mpmc::Sender<DecisionBatch>,
        max_seq_len: usize,
    ) -> SamplerStats {
        let mut stats = SamplerStats::default();
        while let Some(msg) = rx.pop() {
            match msg {
                SamplerMsg::Register { seq_id, prompt, output, params, grammar } => {
                    if self.owns(seq_id) {
                        // resumed sequence: replay pre-preemption decisions
                        // into the history and the grammar state
                        let hist = BatchHistory::with_replay(prompt, &output, max_seq_len);
                        let mut grammar = grammar.map(|g| {
                            let s = g.start();
                            (g, s)
                        });
                        for &t in &output {
                            if let Some((g, state)) = &mut grammar {
                                if let Some(next) = g.advance(*state, t) {
                                    *state = next;
                                }
                            }
                        }
                        self.owned.insert(seq_id, OwnedSeq { hist, params, grammar });
                    }
                }
                SamplerMsg::Retire { seq_id } => {
                    if self.owns(seq_id) {
                        self.owned.remove(&seq_id);
                    }
                }
                SamplerMsg::Iterate(task) => {
                    let t0 = Instant::now();
                    let mut decisions = Vec::new();
                    for (ci, meta) in task.columns.iter().enumerate() {
                        if !self.owns(meta.seq_id) {
                            continue;
                        }
                        let Some(seq) = self.owned.get_mut(&meta.seq_id) else {
                            continue; // retired concurrently; engine resends
                        };
                        let draft: &[u32] =
                            task.drafts.get(ci).map(Vec::as_slice).unwrap_or(&[]);
                        // One code path for both modes: with an empty draft
                        // this is exactly one grammar-masked decision plus
                        // the local metadata append (§5.1); with a draft it
                        // is batched rejection verification with
                        // roll-forward/rollback of the owned state.
                        let verdict = verify::verify_window(
                            &mut self.pipeline,
                            &task.views,
                            meta.col,
                            draft,
                            &mut seq.hist,
                            &mut seq.grammar,
                            &seq.params,
                            &task.pre,
                            meta.seq_id,
                            meta.iteration,
                        );
                        decisions.push((meta.col, meta.seq_id, verdict));
                    }
                    let busy = t0.elapsed().as_secs_f64();
                    stats.busy_s += busy;
                    let batch = DecisionBatch {
                        iter: task.iter,
                        sampler_id: self.id,
                        decisions,
                        busy_s: busy,
                    };
                    if tx.send(batch).is_err() {
                        break; // engine gone
                    }
                }
            }
        }
        stats.decisions = self.pipeline.decisions;
        stats.fast_path_hits = self.pipeline.fast_path_hits;
        stats.alpha_sum = self.pipeline.alpha_sum;
        stats
    }
}

impl SamplerService {
    /// Spawn `cfg.num_samplers` workers. `hot` is required for the SHVS
    /// variant; `vocab` sizes the default hot set if none is given.
    pub fn start(cfg: &SamplerConfig, hot: Option<Arc<HotVocab>>, max_seq_len: usize) -> Self {
        let m = cfg.num_samplers.max(1);
        let (result_tx, results) = mpmc::channel::<DecisionBatch>(m * cfg.ring_depth.max(1) * 2);
        let mut senders = Vec::with_capacity(m);
        let mut workers = Vec::with_capacity(m);
        for id in 0..m {
            let (tx, rx) = spsc::ring::<SamplerMsg>(cfg.ring_depth.max(1) * 64);
            let worker = SamplerWorker {
                id,
                m,
                pipeline: DecisionPipeline::new(cfg.variant, hot.clone(), cfg.seed),
                owned: HashMap::new(),
            };
            let result_tx = result_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("sampler-{id}"))
                .spawn(move || worker.run(rx, result_tx, max_seq_len))
                .expect("spawn sampler");
            senders.push(tx);
            workers.push(handle);
        }
        drop(result_tx);
        SamplerService { senders, results, workers, m }
    }

    pub fn num_samplers(&self) -> usize {
        self.m
    }

    /// Register a new sequence (broadcast; only the owner keeps it).
    pub fn register(&self, seq_id: u64, prompt: &[u32], params: &SamplingParams) {
        self.register_full(seq_id, prompt, &[], params, None);
    }

    /// Register with an optional structured-decoding constraint.
    pub fn register_with_grammar(
        &self,
        seq_id: u64,
        prompt: &[u32],
        params: &SamplingParams,
        grammar: Option<Arc<GrammarConstraint>>,
    ) {
        self.register_full(seq_id, prompt, &[], params, grammar);
    }

    /// Register a (possibly resumed) sequence: `output` carries tokens
    /// generated before a preemption, replayed into the owner's local state.
    pub fn register_full(
        &self,
        seq_id: u64,
        prompt: &[u32],
        output: &[u32],
        params: &SamplingParams,
        grammar: Option<Arc<GrammarConstraint>>,
    ) {
        let owner = (seq_id as usize) % self.m;
        self.senders[owner].push(SamplerMsg::Register {
            seq_id,
            prompt: prompt.to_vec(),
            output: output.to_vec(),
            params: params.clone(),
            grammar,
        });
    }

    /// Retire a finished sequence.
    pub fn retire(&self, seq_id: u64) {
        let owner = (seq_id as usize) % self.m;
        self.senders[owner].push(SamplerMsg::Retire { seq_id });
    }

    /// Publish one iteration's logits + metadata to all samplers.
    pub fn submit(&self, task: IterationTask) {
        let task = Arc::new(task);
        for tx in &self.senders {
            tx.push(SamplerMsg::Iterate(task.clone()));
        }
    }

    /// Collect decisions for iteration `iter` (blocks until all `m` sampler
    /// batches for that iteration arrived). Returns (col → (seq, verdict))
    /// plus the max per-sampler busy time (the decision-plane latency that
    /// must hide under GPU compute).
    pub fn collect(&self, iter: u64, expected_cols: usize) -> (Vec<(usize, u64, Verdict)>, f64) {
        let mut got = Vec::with_capacity(expected_cols);
        let mut batches = 0usize;
        let mut max_busy = 0.0f64;
        while batches < self.m {
            match self.results.recv() {
                Some(batch) => {
                    debug_assert_eq!(batch.iter, iter, "iteration interleave");
                    max_busy = max_busy.max(batch.busy_s);
                    got.extend(batch.decisions);
                    batches += 1;
                }
                None => break,
            }
        }
        got.sort_unstable_by_key(|&(col, _, _)| col);
        (got, max_busy)
    }

    /// Shut down and return per-sampler stats.
    pub fn shutdown(self) -> Vec<SamplerStats> {
        for tx in &self.senders {
            tx.close();
        }
        drop(self.senders);
        drop(self.results);
        self.workers
            .into_iter()
            .map(|w| w.join().expect("sampler panicked"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decision::draft::DraftProposer;
    use crate::harness::measure::LogitsGen;
    use crate::tensor::{shard_row_major, Tensor2};

    fn logits_view(b: usize, v: usize, iter: u64, shards: usize) -> ShardedLogits {
        let data: Vec<f32> = (0..b * v)
            .map(|i| {
                let x = (i as u64).wrapping_mul(2654435761).wrapping_add(iter * 97);
                ((x % 1000) as f32) / 150.0 - 3.0
            })
            .collect();
        shard_row_major(&Tensor2::from_vec(b, v, data), shards)
    }

    fn run_service(m: usize, variant: DecisionVariant, iters: u64) -> Vec<Vec<u32>> {
        let v = 64;
        let b = 6;
        let cfg = SamplerConfig {
            num_samplers: m,
            variant,
            seed: 42,
            ..Default::default()
        };
        let hot = HotVocab::new((0..16).collect(), v).into_arc();
        let svc = SamplerService::start(&cfg, Some(hot), 128);
        let params = SamplingParams::production_default();
        for s in 0..b as u64 {
            svc.register(s, &[1, 2, 3], &params);
        }
        let mut streams: Vec<Vec<u32>> = vec![Vec::new(); b];
        for iter in 0..iters {
            let view = logits_view(b, v, iter, 2);
            let columns: Vec<ColumnMeta> = (0..b)
                .map(|col| ColumnMeta { col, seq_id: col as u64, iteration: iter })
                .collect();
            svc.submit(IterationTask::single(iter, view, columns, Vec::new()));
            let (decisions, _busy) = svc.collect(iter, b);
            assert_eq!(decisions.len(), b, "every column decided");
            for (col, seq, verdict) in decisions {
                assert_eq!(col as u64, seq);
                assert_eq!(verdict.tokens.len(), 1, "non-speculative: one token");
                streams[col].push(verdict.tokens[0]);
            }
        }
        for s in 0..b as u64 {
            svc.retire(s);
        }
        let stats = svc.shutdown();
        assert_eq!(stats.len(), m);
        let total: u64 = stats.iter().map(|s| s.decisions).sum();
        assert_eq!(total, iters * b as u64);
        streams
    }

    /// Drive the service with speculative windows of size `k` until every
    /// sequence committed ≥ `total` tokens. Logits are keyed by
    /// (seq, decode_iter) — the context-free synthetic data plane — so the
    /// streams must be bit-identical across `k` and `m`.
    fn run_service_spec(m: usize, k: usize, total: usize) -> Vec<Vec<u32>> {
        let vocab = 256;
        let b = 4usize;
        let gen = LogitsGen::new(vocab, 1.1, 5);
        let proposer = DraftProposer::new();
        let cfg = SamplerConfig {
            num_samplers: m,
            variant: DecisionVariant::Offloading,
            seed: 17,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 512);
        let prompts: Vec<Vec<u32>> = (0..b).map(|s| vec![s as u32 + 1, 9]).collect();
        let params: Vec<SamplingParams> = (0..b)
            .map(|s| SamplingParams { seed: s as u64, ..SamplingParams::production_default() })
            .collect();
        for s in 0..b {
            svc.register(s as u64, &prompts[s], &params[s]);
        }
        let mut streams: Vec<Vec<u32>> = vec![Vec::new(); b];
        let mut iter = 0u64;
        while streams.iter().any(|s| s.len() < total) {
            let live: Vec<usize> =
                (0..b).filter(|&s| streams[s].len() < total).collect();
            let drafts: Vec<Vec<u32>> = live
                .iter()
                .map(|&s| {
                    proposer.propose(params[s].seed, vocab, &prompts[s], &streams[s], k)
                })
                .collect();
            let kmax = drafts.iter().map(Vec::len).max().unwrap_or(0);
            let columns: Vec<ColumnMeta> = live
                .iter()
                .enumerate()
                .map(|(col, &s)| ColumnMeta {
                    col,
                    seq_id: s as u64,
                    iteration: streams[s].len() as u64,
                })
                .collect();
            // view j: per-column logits at that column's decode_iter + j
            let views: Vec<ShardedLogits> = (0..=kmax as u64)
                .map(|j| {
                    let keys: Vec<(u64, u64)> = live
                        .iter()
                        .map(|&s| (s as u64, streams[s].len() as u64 + j))
                        .collect();
                    gen.seq_view(&keys, 2)
                })
                .collect();
            svc.submit(IterationTask {
                iter,
                views,
                columns: Arc::new(columns),
                pre: Arc::new(Vec::new()),
                drafts: Arc::new(drafts),
            });
            let (decisions, _busy) = svc.collect(iter, live.len());
            assert_eq!(decisions.len(), live.len());
            for (col, seq, verdict) in decisions {
                let _ = col;
                streams[seq as usize].extend(&verdict.tokens);
            }
            iter += 1;
        }
        for s in 0..b as u64 {
            svc.retire(s);
        }
        svc.shutdown();
        for s in streams.iter_mut() {
            s.truncate(total);
        }
        streams
    }

    #[test]
    fn speculative_streams_bit_identical_across_k_and_m() {
        // The tentpole's end-to-end service contract: verified speculative
        // decode commits the same stream as plain decode for any window
        // size k and any sampler count m.
        let baseline = run_service_spec(1, 0, 24);
        for (m, k) in [(1usize, 2usize), (2, 2), (4, 4), (2, 3)] {
            let spec = run_service_spec(m, k, 24);
            assert_eq!(spec, baseline, "m={m} k={k}");
        }
    }

    #[test]
    fn service_decides_all_columns() {
        let streams = run_service(3, DecisionVariant::Offloading, 8);
        assert!(streams.iter().all(|s| s.len() == 8));
    }

    #[test]
    fn token_streams_invariant_to_sampler_count() {
        // §5.1 determinism: m=1 and m=4 must produce identical tokens.
        let a = run_service(1, DecisionVariant::Offloading, 10);
        let b = run_service(4, DecisionVariant::Offloading, 10);
        assert_eq!(a, b);
        let c = run_service(2, DecisionVariant::Shvs, 10);
        let d = run_service(5, DecisionVariant::Shvs, 10);
        assert_eq!(c, d);
    }

    #[test]
    fn shvs_service_matches_offloading_distributionally() {
        // Not token-exact (different uniform usage) but same distribution —
        // light smoke here; the heavy TVD check lives in shvs::tests.
        let a = run_service(2, DecisionVariant::Shvs, 30);
        let b = run_service(2, DecisionVariant::Offloading, 30);
        // same length streams, tokens within vocab
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.len(), y.len());
            assert!(x.iter().all(|&t| (t as usize) < 64));
            assert!(y.iter().all(|&t| (t as usize) < 64));
        }
    }

    #[test]
    fn retire_frees_ownership() {
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 64);
        let params = SamplingParams::default();
        svc.register(7, &[1], &params);
        svc.retire(7);
        // Iterating a retired sequence: no decision is produced for it.
        let view = logits_view(1, 32, 0, 1);
        svc.submit(IterationTask::single(
            0,
            view,
            vec![ColumnMeta { col: 0, seq_id: 7, iteration: 0 }],
            Vec::new(),
        ));
        let (decisions, _) = svc.collect(0, 0);
        assert!(decisions.is_empty());
        svc.shutdown();
    }
}
