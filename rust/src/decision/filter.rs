//! Truncation-first filtering (§5.2).
//!
//! Composes the enabled constraints (allow-list, top-k, nucleus top-p,
//! min-p) into the per-sequence subset `K_b` with its index map
//! `π_b : {1..|K_b|} → {1..V}`, *then* normalizes only on the subset:
//! `softmax(z|_{K_b}/τ)` equals the masked softmax over V restricted to K_b
//! (shift-invariance), but costs O(|K_b|) instead of O(V) downstream.
//!
//! Filter chain semantics follow vLLM/HF logits processors: top-k keeps the
//! k largest logits; top-p keeps the smallest prefix of the *renormalized*
//! remaining distribution with cumulative mass ≥ p; min-p drops tokens with
//! p < min_p · p_max. Selection uses quickselect (average O(n)), not a full
//! sort — the "single-pass, linear-time" claim of §5.2; the naive baseline's
//! full-sort variant is kept for the Figure 10 ablation.

use super::params::SamplingParams;

/// The truncated candidate set: ids are the index map π_b back to the full
/// vocabulary, `weights[i] = exp((z_i − z_max)/τ)` are unnormalized softmax
/// weights over the subset, `sum` their total. Sampling draws from
/// `weights/sum`; this *is* the truncated stable softmax.
///
/// Canonical ordering invariant: `ids` is always ascending, and `sum` is the
/// left-to-right f64 sum of `weights` in that id order. Every producer
/// (quickselect, sort-based, SIMD) must emit this exact layout so the
/// bit-identical-streams invariant holds across kernel backends.
#[derive(Debug, Clone)]
pub struct Truncated {
    pub ids: Vec<u32>,
    pub weights: Vec<f64>,
    pub sum: f64,
    /// Max (temperature-scaled) logit used as the stable-softmax shift.
    pub z_max: f32,
}

impl Truncated {
    pub fn len(&self) -> usize {
        self.ids.len()
    }
    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }
    /// Normalized probability of subset index i.
    pub fn prob(&self, i: usize) -> f64 {
        self.weights[i] / self.sum
    }
}

/// Quickselect: partition `items` so the `k` largest items occupy
/// `items[..k]` (order within unspecified). Average O(n) via std's
/// introselect (`select_nth_unstable_by`).
///
/// Ties at the kth logit break by **lowest id wins**: the comparator is the
/// total order (logit desc, id asc), so the selected top-k *set* is unique
/// and backend-independent even with duplicate logits.
pub fn select_top_k(items: &mut [(u32, f32)], k: usize) {
    if k == 0 || k >= items.len() {
        return;
    }
    items.select_nth_unstable_by(k - 1, |a, b| {
        b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
    });
}

/// Apply the truncation-first chain to penalized candidates `(id, logit)`.
/// `candidates` is consumed and reused as scratch.
///
/// For greedy requests (τ = 0) the result is the singleton argmax.
pub fn truncate(mut candidates: Vec<(u32, f32)>, p: &SamplingParams) -> Truncated {
    assert!(!candidates.is_empty(), "no candidates to sample from");

    if p.is_greedy() {
        let &(id, z) = candidates
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(b.0.cmp(&a.0)))
            .unwrap();
        return Truncated { ids: vec![id], weights: vec![1.0], sum: 1.0, z_max: z };
    }

    // 1. top-k (quickselect, O(n)); survivors restored to ascending-id
    // order so stage 2's f64 accumulation order is backend-independent.
    if p.top_k > 0 && p.top_k < candidates.len() {
        select_top_k(&mut candidates, p.top_k);
        candidates.truncate(p.top_k);
        candidates.sort_unstable_by_key(|&(id, _)| id);
    }

    // 2. temperature + stable weights over the survivors
    let inv_tau = 1.0 / p.temperature;
    let z_max = candidates
        .iter()
        .map(|&(_, z)| z)
        .fold(f32::NEG_INFINITY, f32::max);
    let mut ids: Vec<u32> = Vec::with_capacity(candidates.len());
    let mut weights: Vec<f64> = Vec::with_capacity(candidates.len());
    let mut sum = 0.0f64;
    for &(id, z) in &candidates {
        let w = (((z - z_max) * inv_tau) as f64).exp();
        ids.push(id);
        weights.push(w);
        sum += w;
    }

    // 3. nucleus top-p on the renormalized survivors
    if p.top_p < 1.0 {
        // Stable sort desc by weight (O(k log k), k already small). Indices
        // are ascending-id, so equal weights at the cutoff keep the lowest
        // id first — the nucleus set is deterministic under ties.
        let mut order: Vec<usize> = (0..ids.len()).collect();
        order.sort_by(|&a, &b| weights[b].partial_cmp(&weights[a]).unwrap());
        let threshold = p.top_p as f64 * sum;
        let mut cum = 0.0;
        let mut keep = Vec::with_capacity(order.len());
        for &i in &order {
            keep.push(i);
            cum += weights[i];
            if cum >= threshold {
                break;
            }
        }
        keep.sort_unstable(); // restore vocab order for determinism
        let new_ids: Vec<u32> = keep.iter().map(|&i| ids[i]).collect();
        let new_w: Vec<f64> = keep.iter().map(|&i| weights[i]).collect();
        sum = new_w.iter().sum();
        ids = new_ids;
        weights = new_w;
    }

    // 4. min-p relative to the max weight: p_i ≥ min_p · p_max ⟺ w_i ≥ min_p · w_max
    if p.min_p > 0.0 {
        let w_max = weights.iter().cloned().fold(0.0f64, f64::max);
        let cut = p.min_p as f64 * w_max;
        let mut new_ids = Vec::with_capacity(ids.len());
        let mut new_w = Vec::with_capacity(ids.len());
        sum = 0.0;
        for (i, &w) in weights.iter().enumerate() {
            if w >= cut {
                new_ids.push(ids[i]);
                new_w.push(w);
                sum += w;
            }
        }
        ids = new_ids;
        weights = new_w;
    }

    debug_assert!(!ids.is_empty());
    Truncated { ids, weights, sum, z_max }
}

/// Naive full-sort variant (the "vLLM CPU" baseline of §7.4): sorts the
/// whole candidate list O(V log V) before truncation. Identical output
/// distribution to [`truncate`]; exists for the ablation ladder.
pub fn truncate_sort_based(mut candidates: Vec<(u32, f32)>, p: &SamplingParams) -> Truncated {
    if p.is_greedy() {
        return truncate(candidates, p);
    }
    candidates.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    if p.top_k > 0 && p.top_k < candidates.len() {
        candidates.truncate(p.top_k);
    }
    // Restore the canonical ascending-id order before delegating so the f64
    // accumulation order matches the quickselect path bit-for-bit.
    candidates.sort_unstable_by_key(|&(id, _)| id);
    let rest = SamplingParams { top_k: 0, ..p.clone() };
    truncate(candidates, &rest)
}

/// Restrict candidates to an allow-list before truncation (constrained
/// decoding). Returns the filtered (id, logit) list.
pub fn apply_allow_list(
    candidates: Vec<(u32, f32)>,
    allowed: &[u32],
) -> Vec<(u32, f32)> {
    // Allow-lists are small; a sorted probe keeps this O(n log a).
    let mut sorted = allowed.to_vec();
    sorted.sort_unstable();
    candidates
        .into_iter()
        .filter(|(id, _)| sorted.binary_search(id).is_ok())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cands(logits: &[f32]) -> Vec<(u32, f32)> {
        logits.iter().enumerate().map(|(i, &z)| (i as u32, z)).collect()
    }

    /// Oracle: full masked softmax over V with sort-based filtering.
    fn oracle_probs(logits: &[f32], p: &SamplingParams) -> Vec<f64> {
        let n = logits.len();
        let mut keep: Vec<bool> = vec![true; n];
        // top-k
        if p.top_k > 0 && p.top_k < n {
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
            for &i in &idx[p.top_k..] {
                keep[i] = false;
            }
        }
        let probs_of = |keep: &[bool]| -> Vec<f64> {
            let z_max = logits
                .iter()
                .zip(keep)
                .filter(|(_, &k)| k)
                .map(|(&z, _)| z)
                .fold(f32::NEG_INFINITY, f32::max);
            let mut w: Vec<f64> = logits
                .iter()
                .zip(keep)
                .map(|(&z, &k)| {
                    if k {
                        (((z - z_max) / p.temperature) as f64).exp()
                    } else {
                        0.0
                    }
                })
                .collect();
            let s: f64 = w.iter().sum();
            for x in &mut w {
                *x /= s;
            }
            w
        };
        // top-p on renormalized
        if p.top_p < 1.0 {
            let probs = probs_of(&keep);
            let mut idx: Vec<usize> = (0..n).filter(|&i| keep[i]).collect();
            idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
            let mut cum = 0.0;
            let mut nucleus = vec![false; n];
            for &i in &idx {
                nucleus[i] = true;
                cum += probs[i];
                if cum >= p.top_p as f64 {
                    break;
                }
            }
            keep = nucleus;
        }
        // min-p
        if p.min_p > 0.0 {
            let probs = probs_of(&keep);
            let pmax = probs.iter().cloned().fold(0.0f64, f64::max);
            for i in 0..n {
                if keep[i] && probs[i] < p.min_p as f64 * pmax {
                    keep[i] = false;
                }
            }
        }
        probs_of(&keep)
    }

    fn assert_matches_oracle(logits: &[f32], p: &SamplingParams) {
        let t = truncate(cands(logits), p);
        let oracle = oracle_probs(logits, p);
        // subset probs must equal oracle at kept ids, zero elsewhere
        let mut got = vec![0.0f64; logits.len()];
        for (i, &id) in t.ids.iter().enumerate() {
            got[id as usize] = t.prob(i);
        }
        for (i, (&g, &o)) in got.iter().zip(&oracle).enumerate() {
            assert!(
                (g - o).abs() < 1e-9,
                "id {i}: got {g} oracle {o} (params {p:?})"
            );
        }
    }

    #[test]
    fn no_filter_equals_full_softmax() {
        let logits = [1.0, 2.0, 3.0, -1.0, 0.5];
        assert_matches_oracle(&logits, &SamplingParams::default());
    }

    #[test]
    fn top_k_matches_oracle() {
        let logits = [1.0, 5.0, 3.0, 2.0, 4.0, -2.0];
        let p = SamplingParams { top_k: 3, ..Default::default() };
        assert_matches_oracle(&logits, &p);
    }

    #[test]
    fn top_p_matches_oracle() {
        let logits = [0.0, 1.0, 2.0, 3.0, 4.0];
        for top_p in [0.5, 0.9, 0.99] {
            let p = SamplingParams { top_p, ..Default::default() };
            assert_matches_oracle(&logits, &p);
        }
    }

    #[test]
    fn min_p_matches_oracle() {
        let logits = [0.0, 1.0, 2.0, 5.0];
        let p = SamplingParams { min_p: 0.1, ..Default::default() };
        assert_matches_oracle(&logits, &p);
    }

    #[test]
    fn full_chain_matches_oracle() {
        let logits: Vec<f32> =
            (0..64).map(|i| ((i * 37 % 64) as f32) / 7.0 - 3.0).collect();
        let p = SamplingParams {
            temperature: 0.7,
            top_k: 20,
            top_p: 0.9,
            min_p: 0.05,
            ..Default::default()
        };
        assert_matches_oracle(&logits, &p);
    }

    #[test]
    fn sort_based_equals_quickselect_path() {
        let logits: Vec<f32> = (0..100).map(|i| ((i * 17 % 100) as f32) * 0.1).collect();
        let p = SamplingParams {
            temperature: 0.8,
            top_k: 13,
            top_p: 0.92,
            min_p: 0.01,
            ..Default::default()
        };
        let a = truncate(cands(&logits), &p);
        let b = truncate_sort_based(cands(&logits), &p);
        let to_map = |t: &Truncated| -> std::collections::BTreeMap<u32, u64> {
            t.ids
                .iter()
                .zip(&t.weights)
                .map(|(&id, &w)| (id, ((w / t.sum) * 1e12) as u64))
                .collect()
        };
        assert_eq!(to_map(&a), to_map(&b));
    }

    #[test]
    fn greedy_returns_argmax_singleton() {
        let logits = [0.1, 7.0, 3.0];
        let t = truncate(cands(&logits), &SamplingParams::greedy());
        assert_eq!(t.ids, vec![1]);
        assert_eq!(t.len(), 1);
        assert!((t.prob(0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn select_top_k_partitions_correctly() {
        let mut rng = crate::rng::Philox::new(31);
        for n in [5usize, 64, 1000] {
            for k in [1usize, 3, n / 2, n - 1] {
                let mut items: Vec<(u32, f32)> = (0..n)
                    .map(|i| (i as u32, rng.next_f32() * 100.0))
                    .collect();
                let mut sorted: Vec<f32> = items.iter().map(|&(_, z)| z).collect();
                sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
                let kth = sorted[k - 1];
                select_top_k(&mut items, k);
                for &(_, z) in &items[..k] {
                    assert!(z >= kth, "top-{k} of {n}: {z} < kth {kth}");
                }
                for &(_, z) in &items[k..] {
                    assert!(z <= kth, "rest of top-{k} of {n}: {z} > kth {kth}");
                }
            }
        }
    }

    #[test]
    fn select_top_k_with_duplicates() {
        let mut items: Vec<(u32, f32)> =
            vec![(0, 1.0), (1, 2.0), (2, 2.0), (3, 2.0), (4, 0.5), (5, 3.0)];
        select_top_k(&mut items, 3);
        let mut top: Vec<f32> = items[..3].iter().map(|&(_, z)| z).collect();
        top.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert_eq!(top, vec![3.0, 2.0, 2.0]);
    }

    #[test]
    fn allow_list_restricts() {
        let c = cands(&[1.0, 2.0, 3.0, 4.0]);
        let filtered = apply_allow_list(c, &[1, 3]);
        let ids: Vec<u32> = filtered.iter().map(|&(id, _)| id).collect();
        assert_eq!(ids, vec![1, 3]);
    }

    #[test]
    fn truncation_preserves_relative_probs() {
        // softmax on K equals masked softmax over V: ratios preserved.
        let logits = [3.0f32, 1.0, 2.0, 0.0];
        let p = SamplingParams { top_k: 2, ..Default::default() };
        let t = truncate(cands(&logits), &p);
        assert_eq!(t.ids, vec![0, 2]);
        let ratio = t.prob(0) / t.prob(1);
        let expect = ((3.0f64 - 2.0).exp()) / 1.0;
        assert!((ratio - expect).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn empty_candidates_panic() {
        truncate(Vec::new(), &SamplingParams::default());
    }

    #[test]
    fn top_k_geq_vocab_is_noop() {
        let logits = [1.0f32, 3.0, 2.0, 0.0];
        let unfiltered = truncate(cands(&logits), &SamplingParams::default());
        for k in [4usize, 5, 1000] {
            let p = SamplingParams { top_k: k, ..Default::default() };
            let t = truncate(cands(&logits), &p);
            assert_eq!(t.ids, vec![0, 1, 2, 3]);
            assert_eq!(t.weights, unfiltered.weights);
            assert_eq!(t.sum.to_bits(), unfiltered.sum.to_bits());
        }
    }

    #[test]
    fn top_p_one_keeps_everything_even_with_ties() {
        let logits = [2.0f32, 2.0, 2.0, 1.0];
        let p = SamplingParams { top_p: 1.0, ..Default::default() };
        let t = truncate(cands(&logits), &p);
        assert_eq!(t.ids, vec![0, 1, 2, 3]);
        let s: f64 = (0..t.len()).map(|i| t.prob(i)).sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn top_p_ties_at_cutoff_keep_lowest_ids() {
        // Four equal weights; top_p = 0.5 keeps exactly the two lowest ids
        // because the nucleus sort is stable over ascending-id indices.
        let logits = [1.0f32, 1.0, 1.0, 1.0];
        let p = SamplingParams { top_p: 0.5, ..Default::default() };
        let t = truncate(cands(&logits), &p);
        assert_eq!(t.ids, vec![0, 1]);
    }

    #[test]
    fn min_p_eliminates_all_but_argmax() {
        let logits = [0.0f32, 10.0, 1.0, 2.0];
        let p = SamplingParams { min_p: 0.999, ..Default::default() };
        let t = truncate(cands(&logits), &p);
        assert_eq!(t.ids, vec![1]);
        assert!((t.prob(0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn top_k_ties_at_kth_keep_lowest_ids() {
        // Total order (logit desc, id asc): top-3 of these is {5, 1, 2}.
        let c =
            vec![(0u32, 1.0f32), (1, 2.0), (2, 2.0), (3, 2.0), (4, 0.5), (5, 3.0)];
        let p = SamplingParams { top_k: 3, ..Default::default() };
        let t = truncate(c, &p);
        assert_eq!(t.ids, vec![1, 2, 5]);
    }

    #[test]
    fn empty_allow_list_rejected_before_filtering() {
        // A grammar dead state yields an empty allow mask, and a user
        // allow-list disjoint from the grammar mask empties the candidates;
        // params validation is the guard that keeps both out of `truncate`
        // (which panics on an empty set).
        assert!(apply_allow_list(cands(&[1.0, 2.0]), &[]).is_empty());
        let grammar_mask = [0u32, 2];
        let user_allow = [1u32, 3];
        let once = apply_allow_list(cands(&[1.0, 2.0, 3.0, 4.0]), &grammar_mask);
        assert!(apply_allow_list(once, &user_allow).is_empty());
        let p = SamplingParams {
            allowed_tokens: Some(vec![]),
            ..Default::default()
        };
        assert!(p.validate(4).is_err());
    }
}
