//! Benchmark harness (criterion is unavailable offline).
//!
//! Measures a closure with warmup + timed iterations, reports mean/p50/p95,
//! and renders markdown tables. `cargo bench` binaries (`benches/*.rs` with
//! `harness = false`) drive this directly.

use crate::metrics::stats::Summary;
use crate::util::fmt_duration;
use std::time::{Duration, Instant};

/// Configuration for one measurement.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop after this much measured time even if < max_iters.
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 200,
            max_time: Duration::from_secs(3),
        }
    }
}

impl BenchConfig {
    /// Fast config for CI/quick mode.
    pub fn quick() -> Self {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            max_time: Duration::from_millis(500),
        }
    }
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration seconds.
    pub samples: Vec<f64>,
    pub summary: Summary,
    /// Optional caller-supplied throughput denominator (items/iter).
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    /// Items per second at the mean iteration time.
    pub fn items_per_sec(&self) -> Option<f64> {
        self.items_per_iter.map(|ipi| {
            if self.summary.mean > 0.0 {
                ipi / self.summary.mean
            } else {
                0.0
            }
        })
    }
}

/// Run one benchmark case. The closure should do one full iteration of work;
/// return values are black-boxed by the caller keeping them observable.
pub fn run_case(
    name: &str,
    cfg: &BenchConfig,
    items_per_iter: Option<f64>,
    mut f: impl FnMut(),
) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.min_iters);
    let started = Instant::now();
    while samples.len() < cfg.max_iters
        && (samples.len() < cfg.min_iters || started.elapsed() < cfg.max_time)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples),
        samples,
        items_per_iter,
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box exists
/// on this toolchain; thin wrapper for call-site clarity).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Render results as a markdown table (p99 included: this repo's headline
/// claims are tail-latency claims, so benches surface the tail too).
pub fn render_table(title: &str, results: &[BenchResult]) -> String {
    let mut out = format!("### {title}\n\n");
    out.push_str("| case | iters | mean | p50 | p95 | p99 | items/s |\n");
    out.push_str("|---|---:|---:|---:|---:|---:|---:|\n");
    for r in results {
        let ips = r
            .items_per_sec()
            .map(|v| format_rate(v))
            .unwrap_or_else(|| "—".into());
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} |\n",
            r.name,
            r.summary.n,
            fmt_duration(Duration::from_secs_f64(r.summary.mean)),
            fmt_duration(Duration::from_secs_f64(r.summary.p50)),
            fmt_duration(Duration::from_secs_f64(r.summary.p95)),
            fmt_duration(Duration::from_secs_f64(r.summary.p99)),
            ips,
        ));
    }
    out
}

/// Machine-readable rendering: one object per case with tail latencies
/// and throughput. `make bench` writes this as `BENCH_decision.json` (and
/// CI uploads it), so the perf trajectory is tracked across PRs instead
/// of living only in scrollback.
pub fn results_to_json(results: &[BenchResult]) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::Arr(
        results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("iters", Json::Num(r.summary.n as f64)),
                    ("mean_s", Json::Num(r.summary.mean)),
                    ("p50_s", Json::Num(r.summary.p50)),
                    ("p95_s", Json::Num(r.summary.p95)),
                    ("p99_s", Json::Num(r.summary.p99)),
                    (
                        "items_per_sec",
                        r.items_per_sec().map(Json::Num).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect(),
    )
}

/// Human-formatted rate (tokens/s etc).
pub fn format_rate(v: f64) -> String {
    if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2}k", v / 1e3)
    } else {
        format!("{v:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_case_collects_samples() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 5,
            max_time: Duration::from_secs(10),
        };
        let mut count = 0;
        let r = run_case("noop", &cfg, Some(100.0), || {
            count += 1;
        });
        assert_eq!(r.samples.len(), 5);
        assert_eq!(count, 6); // 1 warmup + 5 measured
        assert!(r.items_per_sec().unwrap() > 0.0);
    }

    #[test]
    fn max_time_bounds_iterations() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            min_iters: 2,
            max_iters: 10_000,
            max_time: Duration::from_millis(30),
        };
        let r = run_case("sleepy", &cfg, None, || {
            std::thread::sleep(Duration::from_millis(5));
        });
        assert!(r.samples.len() >= 2);
        assert!(r.samples.len() < 100);
    }

    #[test]
    fn table_renders_all_rows() {
        let cfg = BenchConfig::quick();
        let a = run_case("a", &cfg, None, || {});
        let b = run_case("b", &cfg, Some(10.0), || {});
        let md = render_table("t", &[a, b]);
        assert!(md.contains("| a |"));
        assert!(md.contains("| b |"));
    }

    #[test]
    fn results_to_json_one_object_per_case() {
        let cfg = BenchConfig::quick();
        let a = run_case("a", &cfg, Some(10.0), || {});
        let b = run_case("b", &cfg, None, || {});
        let j = results_to_json(&[a, b]);
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").as_str(), Some("a"));
        assert!(arr[0].get("items_per_sec").as_f64().unwrap() > 0.0);
        assert!(arr[1].get("items_per_sec").as_f64().is_none());
        assert!(arr[0].get("p99_s").as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn format_rate_units() {
        assert_eq!(format_rate(5.0), "5.0");
        assert_eq!(format_rate(5_300.0), "5.30k");
        assert_eq!(format_rate(2_500_000.0), "2.50M");
    }
}
