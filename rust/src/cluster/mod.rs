//! Cluster layer (DESIGN.md §9): data-parallel engine replicas behind a
//! decision-plane-aware router, with an optionally *shared* sampler pool.
//!
//! The paper disaggregates sampling from GPU inference along the stage
//! axis; this layer makes the decision plane **replica-agnostic** too —
//! one CPU sampler pool can serve a whole fleet of `Engine<D>` replicas,
//! pooling decision capacity instead of stranding `m` samplers per
//! replica. On top of the replicas sit pluggable routing policies
//! (round-robin, least-outstanding, KV-pressure, session affinity,
//! prefix-cache) and an
//! optional DistServe-style prefill/decode split with a simulated
//! KV-transfer cost, mirrored by `simulator::serving::simulate_cluster`
//! so measured and simulated cluster throughput can be compared.
//!
//! Hard invariant, inherited from every layer below: routing moves work,
//! never changes decisions — per-sequence token streams are bit-identical
//! to a single-replica engine for every policy, replica count, sampler
//! count, `spec_k`, and `n_microbatches`.

pub mod replica;
pub mod router;

pub use replica::{Replica, ReplicaResult, ReplicaRole, ReplicaStatus};
pub use router::{Cluster, ClusterConfig, ClusterReport, ReplicaSummary, RoutePolicy};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DecisionVariant, EngineConfig};
    use crate::engine::{Engine, Request, SyntheticRuntime};
    use crate::workload::{self, TraceConfig};
    use std::collections::HashMap;

    const VOCAB: usize = 512;
    const MAX_SEQ: usize = 96;
    const BATCH: usize = 4;
    const PLANE_SEED: u64 = 29;

    fn engine_cfg(m: usize) -> EngineConfig {
        let mut cfg = EngineConfig::default();
        cfg.sampler.variant = DecisionVariant::Offloading;
        cfg.sampler.num_samplers = m;
        cfg.sampler.seed = 77;
        cfg.idle_poll_us = 20;
        cfg
    }

    fn trace(n: usize) -> Vec<Request> {
        workload::generate(&TraceConfig::tiny(n, VOCAB)).requests
    }

    /// The ground truth: one engine serving the whole trace.
    fn single_engine_streams(n: usize, m: usize) -> HashMap<u64, Vec<u32>> {
        let cfg = engine_cfg(m);
        let runtime = SyntheticRuntime::new(BATCH, VOCAB, MAX_SEQ, PLANE_SEED);
        let mut engine = Engine::new(runtime, &cfg, None);
        for r in trace(n) {
            engine.submit(r);
        }
        engine.run_until_idle().expect("single engine run");
        let streams = engine
            .take_finished()
            .into_iter()
            .map(|f| (f.request.id, f.output))
            .collect();
        engine.shutdown();
        streams
    }

    fn run_cluster(n: usize, ccfg: &ClusterConfig, m: usize) -> ClusterReport {
        let cfg = engine_cfg(m);
        let mut cluster = Cluster::start(
            &cfg,
            ccfg,
            None,
            MAX_SEQ,
            |_id| Ok(SyntheticRuntime::new(BATCH, VOCAB, MAX_SEQ, PLANE_SEED)),
        );
        cluster.run(trace(n)).expect("cluster run");
        cluster.shutdown().expect("cluster shutdown")
    }

    fn streams_of(report: &ClusterReport) -> HashMap<u64, Vec<u32>> {
        report
            .finished
            .iter()
            .map(|s| (s.request.id, s.output.clone()))
            .collect()
    }

    #[test]
    fn cluster_config_applies_cli_args() {
        use crate::util::argparse::{Args, OptSpec};
        let argv: Vec<String> = [
            "p", "--replicas", "4", "--route", "kv", "--shared_samplers",
            "--prefill_replicas", "1", "--kv_transfer_us", "3.5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let specs = [
            OptSpec::value("replicas", ""),
            OptSpec::value("route", ""),
            OptSpec::flag("shared_samplers", ""),
            OptSpec::value("prefill_replicas", ""),
            OptSpec::value("kv_transfer_us", ""),
        ];
        let args = Args::parse(&argv, &specs, false).unwrap();
        let mut cfg = ClusterConfig::default();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.replicas, 4);
        assert_eq!(cfg.policy, RoutePolicy::KvPressure);
        assert!(cfg.shared_samplers);
        assert_eq!(cfg.prefill_replicas, 1);
        assert!((cfg.kv_transfer_us_per_token - 3.5).abs() < 1e-12);
    }

    #[test]
    fn route_policy_parse_roundtrip() {
        for p in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("kv"), Some(RoutePolicy::KvPressure));
        assert_eq!(RoutePolicy::parse("bogus"), None);
    }

    #[test]
    fn every_policy_matches_the_single_engine_streams() {
        let n = 14;
        let want = single_engine_streams(n, 2);
        assert_eq!(want.len(), n);
        for policy in RoutePolicy::ALL {
            let mut ccfg = ClusterConfig::default();
            ccfg.replicas = 2;
            ccfg.policy = policy;
            let report = run_cluster(n, &ccfg, 2);
            assert_eq!(
                streams_of(&report),
                want,
                "policy {} must not change tokens",
                policy.name()
            );
            assert_eq!(report.recorder.finished_requests(), n);
        }
    }

    #[test]
    fn session_affinity_colocates_shared_block_sessions() {
        // Eight sessions whose prompts share the same full first KV block
        // (kv_block_tokens = 16) but diverge after it: the block-aligned
        // session key must land every one on the same replica.
        let shared: Vec<u32> = (40..56).collect();
        let reqs: Vec<Request> = (0..8u64)
            .map(|id| {
                let mut p = shared.clone();
                p.extend([100 + id as u32, 200 + id as u32]);
                Request::new(id, p, 4)
            })
            .collect();
        let mut ccfg = ClusterConfig::default();
        ccfg.replicas = 2;
        ccfg.policy = RoutePolicy::SessionAffinity;
        let cfg = engine_cfg(1);
        let mut cluster = Cluster::start(&cfg, &ccfg, None, MAX_SEQ, |_id| {
            Ok(SyntheticRuntime::new(BATCH, VOCAB, MAX_SEQ, PLANE_SEED))
        });
        cluster.run(reqs).expect("cluster run");
        let report = cluster.shutdown().expect("cluster shutdown");
        assert_eq!(report.finished.len(), 8);
        let busy: Vec<usize> = report
            .per_replica
            .iter()
            .filter(|r| r.summary.tokens > 0)
            .map(|r| r.id)
            .collect();
        assert_eq!(busy.len(), 1, "shared-block sessions must co-locate: {busy:?}");
    }

    #[test]
    fn shared_pool_matches_per_replica_pools() {
        let n = 12;
        let want = single_engine_streams(n, 2);
        let mut ccfg = ClusterConfig::default();
        ccfg.replicas = 2;
        ccfg.policy = RoutePolicy::LeastOutstanding;
        // per-replica pools: 2 × m=2
        let per = run_cluster(n, &ccfg, 2);
        assert_eq!(streams_of(&per), want);
        // one shared pool: m=2 total, serving both replicas
        ccfg.shared_samplers = true;
        let shared = run_cluster(n, &ccfg, 2);
        assert_eq!(streams_of(&shared), want, "shared pool must not change tokens");
        // shared mode reports exactly the pool's m samplers
        assert_eq!(shared.sampler_stats.len(), 2);
        let decided: u64 = shared.sampler_stats.iter().map(|s| s.decisions).sum();
        assert!(decided > 0, "the shared pool actually decided");
    }

    #[test]
    fn prefill_decode_split_hands_off_and_matches_streams() {
        let n = 12;
        let want = single_engine_streams(n, 2);
        let mut ccfg = ClusterConfig::default();
        ccfg.replicas = 3;
        ccfg.prefill_replicas = 1;
        ccfg.kv_transfer_us_per_token = 5.0;
        let report = run_cluster(n, &ccfg, 2);
        assert_eq!(
            streams_of(&report),
            want,
            "handoff + recompute + transfer delay must not change tokens"
        );
        // roles recorded per replica; the prefill replica saw work
        assert_eq!(report.per_replica[0].role, ReplicaRole::Prefill);
        assert!(report.per_replica[0].summary.tokens > 0);
        // decode replicas produced the bulk of the tokens
        let decode_tokens: usize = report.per_replica[1..]
            .iter()
            .map(|r| r.summary.tokens)
            .sum();
        assert!(decode_tokens > report.per_replica[0].summary.tokens);
    }

    #[test]
    fn merged_recorder_counts_every_token_once() {
        let mut ccfg = ClusterConfig::default();
        ccfg.replicas = 2;
        let report = run_cluster(10, &ccfg, 1);
        let expected: usize = report.finished.iter().map(|s| s.output.len()).sum();
        assert_eq!(report.recorder.total_tokens(), expected);
        let agg = report.recorder.summary();
        assert_eq!(agg.finished, 10);
        // per-replica token counts partition the fleet total
        let split: usize = report.per_replica.iter().map(|r| r.summary.tokens).sum();
        assert_eq!(split, expected);
    }
}
