//! A data-parallel engine replica: one [`Engine`] running on its own
//! worker thread behind a submit/reap ring pair (DESIGN.md §9).
//!
//! The mailboxes are bounded lock-free MPMC rings
//! ([`crate::ringbuf::mpmc::Ring`]) rather than mutexed queues, so the
//! router's routing hot path and the worker's drain never contend on a
//! lock — the same submit discipline the shared sampler pool uses. The
//! worker drains its inbox into the engine, runs one executor turn
//! ([`Engine::step_once`]), refreshes a lock-free heartbeat (queue depth,
//! live KV-block occupancy), and hands finished sequences back through
//! its outbox. When the engine is fully drained the worker
//! polls the inbox at the replica's `idle_poll_us` quantum — the same
//! bounded-poll discipline as the engine's own arrival wait — and exits
//! only on a requested stop *with an empty inbox*, so a shutdown can
//! never strand an in-flight or still-routed sequence (join-on-shutdown,
//! mirroring the sampler service's join-on-death).
//!
//! **Routing invariant.** Replicas are interchangeable decision-wise: a
//! sequence's logits depend only on its own fed-token prefix (every
//! replica loads the same model / the same synthetic plane seed) and its
//! decisions are keyed by (sampler seed, request seed, sequence,
//! iteration). Which replica a sequence lands on — or whether it is
//! handed off mid-lifecycle — changes timing, never tokens.
//!
//! **Failure domain (DESIGN.md §10).** A replica worker can die mid-run
//! (an engine error, a panic, or a chaos-injected kill). The router's
//! failure sweep reaps the corpse through [`Replica::try_reap_failure`]
//! and — with failover enabled — requeues its outstanding sequences onto
//! survivors via `submit_resumed`, the same recompute path the
//! prefill→decode handoff uses; the interchangeability invariant above is
//! exactly why the requeued sequences' streams stay bit-identical.

use crate::config::EngineConfig;
use crate::decision::service::{SamplerService, SamplerStats, TASK_NS_SHIFT};
use crate::decision::HotVocab;
use crate::engine::{DataPlane, Engine, Request, Sequence};
use crate::metrics::Recorder;
use crate::ringbuf::mpmc;
use crate::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Mailbox ring capacity. Routing is paced by arrivals and the worker
/// drains every turn, so a burst beyond this depth merely backpressures
/// the router's push (spin-then-yield) — it never drops or reorders.
const MAILBOX_DEPTH: usize = 1024;

/// Role in the optional DistServe-style split: `Unified` replicas serve
/// whole lifecycles; `Prefill` replicas serve a request truncated to its
/// first token (the TTFT work) and the router hands the sequence off;
/// `Decode` replicas resume it with recompute after the simulated
/// KV-transfer delay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaRole {
    Unified,
    Prefill,
    Decode,
}

impl ReplicaRole {
    pub fn name(self) -> &'static str {
        match self {
            ReplicaRole::Unified => "unified",
            ReplicaRole::Prefill => "prefill",
            ReplicaRole::Decode => "decode",
        }
    }
}

/// Lock-free heartbeat the worker refreshes every executor turn; the
/// router reads it for the load-aware policies (`LeastOutstanding` reads
/// `depth`, `KvPressure` reads `kv_free_blocks`). End-of-run quantities
/// (preemptions, token counts) travel in [`ReplicaResult`] instead.
#[derive(Debug)]
pub struct ReplicaStatus {
    /// Waiting + running sequences inside the engine.
    pub depth: AtomicUsize,
    /// Free KV blocks right now (live occupancy).
    pub kv_free_blocks: AtomicUsize,
}

// Manual impl: the loom-shimmed atomics (`--cfg loom`) don't implement
// `Default`, so `#[derive(Default)]` would not compile under the model
// checker.
impl Default for ReplicaStatus {
    fn default() -> Self {
        ReplicaStatus { depth: AtomicUsize::new(0), kv_free_blocks: AtomicUsize::new(0) }
    }
}

/// Inbound work: fresh requests, or resumes (prefill→decode handoffs and
/// failover requeues) carrying the tokens generated before the transfer.
enum Inbound {
    Submit(Request),
    Resume(Request, Vec<u32>),
}

/// What a worker returns at join time.
pub struct ReplicaResult {
    pub recorder: Recorder,
    pub sampler_stats: Vec<SamplerStats>,
    pub preemptions: u64,
    /// Speculative-decoding tallies over committed windows (see
    /// `Engine::spec_accepted` — the fleet report sums them).
    pub spec_accepted: u64,
    pub spec_proposed: u64,
    pub spec_committed: u64,
    pub spec_windows: u64,
    /// Prefill tokens actually computed vs skipped via prefix-cache hits
    /// (DESIGN.md §13) — the fleet report sums them.
    pub prefill_computed: u64,
    pub prefill_skipped: u64,
}

/// Router-side handle to a running replica.
pub struct Replica {
    pub id: usize,
    pub role: ReplicaRole,
    inbox: mpmc::Ring<Inbound>,
    outbox: mpmc::Ring<Sequence>,
    status: Arc<ReplicaStatus>,
    stop: Arc<AtomicBool>,
    /// Chaos injection: makes the worker panic at the top of its loop.
    kill: Arc<AtomicBool>,
    /// Set once the router reaped this replica's corpse (failover mode):
    /// it takes no further routing and is skipped at shutdown.
    dead: bool,
    handle: Option<JoinHandle<crate::Result<ReplicaResult>>>,
}

/// Render a worker panic payload for error surfacing (the same shape the
/// sampler service uses).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl Replica {
    /// Spawn a replica. The data plane is built *inside* the worker thread
    /// (`make_plane`), so planes that must not cross threads — the PJRT
    /// runtime's client handles — still work; only the factory is `Send`.
    /// With `pool` set the engine submits into the shared sampler service,
    /// namespacing its task ids with `(id + 1) << TASK_NS_SHIFT`; otherwise
    /// it spawns its own samplers timestamped against the cluster `epoch`.
    pub fn spawn<D, F>(
        id: usize,
        role: ReplicaRole,
        cfg: EngineConfig,
        hot: Option<Arc<HotVocab>>,
        pool: Option<Arc<SamplerService>>,
        epoch: Instant,
        make_plane: F,
    ) -> Replica
    where
        D: DataPlane + 'static,
        F: FnOnce() -> crate::Result<D> + Send + 'static,
    {
        let inbox: mpmc::Ring<Inbound> = mpmc::Ring::new(MAILBOX_DEPTH);
        let outbox: mpmc::Ring<Sequence> = mpmc::Ring::new(MAILBOX_DEPTH);
        let status = Arc::new(ReplicaStatus::default());
        let stop = Arc::new(AtomicBool::new(false));
        let kill = Arc::new(AtomicBool::new(false));
        let (w_inbox, w_outbox, w_status, w_stop, w_kill) = (
            inbox.clone(),
            outbox.clone(),
            status.clone(),
            stop.clone(),
            kill.clone(),
        );
        let handle = std::thread::Builder::new()
            .name(format!("replica-{id}"))
            .spawn(move || {
                // Trace lane: pid r+1 = replica r, engine-thread role.
                crate::trace::register_thread(id as u32 + 1, crate::trace::TID_ENGINE);
                let idle_poll_us = cfg.idle_poll_us;
                let plane = make_plane()?;
                let engine = match pool {
                    Some(svc) => Engine::with_shared_service(
                        plane,
                        &cfg,
                        hot,
                        svc,
                        (id as u64 + 1) << TASK_NS_SHIFT,
                    ),
                    None => Engine::with_epoch(plane, &cfg, hot, epoch),
                };
                run_worker(
                    id, engine, w_inbox, w_outbox, w_status, w_stop, w_kill, idle_poll_us,
                )
            })
            .expect("spawn replica");
        Replica {
            id,
            role,
            inbox,
            outbox,
            status,
            stop,
            kill,
            dead: false,
            handle: Some(handle),
        }
    }

    /// The task-id namespace this replica uses in a shared sampler pool.
    pub fn task_namespace(&self) -> u64 {
        (self.id as u64 + 1) << TASK_NS_SHIFT
    }

    /// Route a fresh request into this replica (lock-free ring push).
    pub fn submit(&self, req: Request) {
        self.inbox.push(Inbound::Submit(req));
    }

    /// Route a resume: a prefill→decode handoff or a failover requeue.
    /// The sequence resumes with recompute and decisions continue from
    /// iteration `output.len()`.
    pub fn submit_resumed(&self, req: Request, output: Vec<u32>) {
        self.inbox.push(Inbound::Resume(req, output));
    }

    /// Routed-but-unadmitted plus in-engine sequences — `LeastOutstanding`'s
    /// load signal.
    pub fn outstanding(&self) -> usize {
        self.inbox.len() + self.status.depth.load(Ordering::Relaxed)
    }

    /// Free KV blocks from the latest heartbeat — `KvPressure`'s signal.
    pub fn kv_free_blocks(&self) -> usize {
        self.status.kv_free_blocks.load(Ordering::Relaxed)
    }

    /// Take whatever finished sequences the worker handed back so far.
    pub fn drain_finished(&self) -> Vec<Sequence> {
        let mut out = Vec::new();
        while let Ok(seq) = self.outbox.try_pop() {
            out.push(seq);
        }
        out
    }

    /// Ask the worker to exit once drained (graceful: in-flight and
    /// already-routed sequences still complete first).
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::Release);
    }

    /// Chaos injection: make the worker thread panic at the top of its
    /// next loop turn — a replica crash with arbitrary in-flight state.
    pub fn inject_kill(&self) {
        self.kill.store(true, Ordering::Release);
    }

    /// Whether the router has reaped this replica after a failure.
    pub fn is_dead(&self) -> bool {
        self.dead
    }

    /// Reap a worker that died *before* a stop was requested — an engine
    /// error or panic. Returns the failure message and marks the replica
    /// dead (it is skipped by routing and shutdown from here on); returns
    /// `None` while the worker is healthy or already reaped.
    pub fn try_reap_failure(&mut self) -> Option<String> {
        let died = self.handle.as_ref().is_some_and(|h| h.is_finished())
            && !self.stop.load(Ordering::Acquire);
        if !died {
            return None;
        }
        self.dead = true;
        let handle = self.handle.take().unwrap();
        Some(match handle.join() {
            Ok(Ok(_)) => format!("replica {} exited mid-run", self.id),
            Ok(Err(e)) => format!("replica {} failed: {e:#}", self.id),
            Err(payload) => format!(
                "replica {} panicked: {}",
                self.id,
                panic_message(payload.as_ref())
            ),
        })
    }

    /// Join the worker (call after [`Self::request_stop`]).
    pub fn join(mut self) -> crate::Result<ReplicaResult> {
        let Some(handle) = self.handle.take() else {
            anyhow::bail!("replica {} already reaped after failure", self.id);
        };
        match handle.join() {
            Ok(res) => res,
            Err(payload) => Err(anyhow::anyhow!(
                "replica {} panicked: {}",
                self.id,
                panic_message(payload.as_ref())
            )),
        }
    }
}

/// The worker loop: drain inbox → one executor turn → heartbeat → hand
/// back finished sequences → bounded idle poll when drained.
#[allow(clippy::too_many_arguments)]
fn run_worker<D: DataPlane>(
    id: usize,
    mut engine: Engine<D>,
    inbox: mpmc::Ring<Inbound>,
    outbox: mpmc::Ring<Sequence>,
    status: Arc<ReplicaStatus>,
    stop: Arc<AtomicBool>,
    kill: Arc<AtomicBool>,
    idle_poll_us: u64,
) -> crate::Result<ReplicaResult> {
    // ordering: Relaxed — single-writer advisory freshness: the heartbeat
    // is a routing hint the router may read one turn stale; no data hangs
    // off it.
    status
        .kv_free_blocks
        .store(engine.kv_free_blocks(), Ordering::Relaxed);
    loop {
        if kill.load(Ordering::Acquire) {
            panic!("chaos: injected replica kill (replica {id})");
        }
        while let Ok(msg) = inbox.try_pop() {
            match msg {
                Inbound::Submit(r) => engine.submit(r),
                Inbound::Resume(r, out) => engine.submit_resumed(r, out),
            }
        }
        let progressed = engine.step_once()?;
        // ordering: Relaxed — single-writer advisory heartbeat (see above);
        // load-aware routing tolerates a stale depth/occupancy by design.
        status.depth.store(engine.queue_depth(), Ordering::Relaxed);
        // ordering: Relaxed — same advisory heartbeat store.
        status
            .kv_free_blocks
            .store(engine.kv_free_blocks(), Ordering::Relaxed);
        for seq in engine.take_finished() {
            outbox.push(seq);
        }
        if !progressed {
            // Fully drained. Exit only on a requested stop with an empty
            // inbox — the router sets stop strictly after collecting every
            // final sequence, so nothing routed is ever dropped.
            if stop.load(Ordering::Acquire) && inbox.is_empty() {
                break;
            }
            if idle_poll_us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(idle_poll_us));
            } else {
                std::thread::yield_now();
            }
        }
    }
    let preemptions = engine.preemption_count();
    let (spec_accepted, spec_proposed, spec_committed, spec_windows) = (
        engine.spec_accepted,
        engine.spec_proposed,
        engine.spec_committed,
        engine.spec_windows,
    );
    let (prefill_computed, prefill_skipped) =
        (engine.prefill_computed_tokens(), engine.prefill_skipped_tokens());
    let (recorder, sampler_stats) = engine.shutdown();
    Ok(ReplicaResult {
        recorder,
        sampler_stats,
        preemptions,
        spec_accepted,
        spec_proposed,
        spec_committed,
        spec_windows,
        prefill_computed,
        prefill_skipped,
    })
}
