//! The cluster front-end: a decision-plane-aware router admitting requests
//! into data-parallel engine replicas (DESIGN.md §9).
//!
//! Five pluggable [`RoutePolicy`]s: `RoundRobin` (placement-blind),
//! `LeastOutstanding` (queue depth from replica heartbeats),
//! `KvPressure` (live KV-block occupancy — the llm-d-style load signal
//! that diverts traffic from a cache-saturated replica *before* it starts
//! preempting), `SessionAffinity` (block-aligned prompt-prefix hash, so
//! shared-prefix traffic lands on the replica whose cache already holds
//! the prefix's working set), and `PrefixCache` (longest-cached-prefix
//! scoring against a router-side approximate index keyed by the same
//! block digests the engines' radix indexes use — DESIGN.md §13).
//!
//! Routing moves work, never decisions: per-sequence token streams are
//! bit-identical to a single-replica engine for every policy, replica
//! count, sampler count, `spec_k`, and `n_microbatches`
//! (`proptests.rs::prop_routed_streams_equal_single_replica`).
//!
//! With `shared_samplers` the router owns one [`SamplerService`] pool that
//! every replica submits into (task ids namespaced per replica), pooling
//! decision-plane capacity instead of stranding it per replica. With
//! `prefill_replicas > 0` the fleet splits DistServe-style: prefill
//! replicas serve each request truncated to its first token, then the
//! router hands the sequence to a decode replica with a simulated
//! KV-transfer delay (`kv_transfer_us_per_token × context`), realized as
//! the resumed request's arrival time.
//!
//! **Replica failover (DESIGN.md §10).** With `failover` on (the
//! default), the router's failure sweep reaps a dead replica and requeues
//! every sequence routed to it onto survivors of the same role through
//! `submit_resumed` — the recompute path the prefill→decode handoff
//! already uses — so a replica crash costs latency, never tokens or
//! sequences. Requeued requests keep their original arrival stamps, so
//! the merged fleet recorder's TTFT/TPOT percentiles absorb the recovery
//! pause exactly; the explicit counters (`ClusterReport::failovers`,
//! `requeued`, `Recorder::recovery_s`) make the cost itself visible.

use super::replica::{Replica, ReplicaRole};
use crate::config::EngineConfig;
use crate::decision::service::{SamplerService, SamplerStats};
use crate::decision::HotVocab;
use crate::engine::{DataPlane, Request, Sequence};
use crate::fault::{FaultKind, FaultPlan};
use crate::metrics::{Recorder, ServingSummary};
use crate::trace;
use crate::util::argparse::Args;
use crate::engine::kvcache;
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::Arc;
use std::time::Instant;

/// Replica-selection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through the candidates — placement-blind baseline.
    RoundRobin,
    /// Fewest routed-but-unfinished sequences (inbox + engine depth).
    LeastOutstanding,
    /// Most free KV blocks in the latest heartbeat, net of
    /// routed-but-unadmitted load (ties: fewest outstanding, then lowest
    /// id) — diverts from cache-saturated replicas before they preempt.
    KvPressure,
    /// Block-aligned prompt-prefix hash, so shared-prefix sessions
    /// co-locate (prompts that can share a cached KV block hash alike).
    SessionAffinity,
    /// Longest cached prefix wins: score each replica by how many leading
    /// block digests of the prompt its approximate router-side index
    /// holds, falling back to KV-pressure on ties (DESIGN.md §13).
    PrefixCache,
}

impl RoutePolicy {
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        Some(match s.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => Self::RoundRobin,
            "lo" | "least" | "least-outstanding" => Self::LeastOutstanding,
            "kv" | "kv-pressure" | "kvpressure" => Self::KvPressure,
            "affinity" | "session" | "session-affinity" => Self::SessionAffinity,
            "prefix" | "prefix-cache" | "prefixcache" => Self::PrefixCache,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::RoundRobin => "round-robin",
            Self::LeastOutstanding => "least-outstanding",
            Self::KvPressure => "kv-pressure",
            Self::SessionAffinity => "session-affinity",
            Self::PrefixCache => "prefix-cache",
        }
    }

    pub const ALL: [RoutePolicy; 5] = [
        Self::RoundRobin,
        Self::LeastOutstanding,
        Self::KvPressure,
        Self::SessionAffinity,
        Self::PrefixCache,
    ];
}

/// Cluster-layer configuration (the engine-layer knobs stay in
/// [`EngineConfig`]; every replica gets a clone of it).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Data-parallel engine replicas.
    pub replicas: usize,
    pub policy: RoutePolicy,
    /// One shared sampler pool for the whole fleet instead of
    /// `replicas × num_samplers` stranded per-replica workers.
    pub shared_samplers: bool,
    /// DistServe-style split: this many replicas serve prefill only and
    /// hand sequences to the remaining decode replicas (0 = unified).
    pub prefill_replicas: usize,
    /// Simulated KV-transfer cost per context token for the prefill→decode
    /// handoff, in microseconds (the decode arrival is delayed by
    /// `context × this`).
    pub kv_transfer_us_per_token: f64,
    /// Router idle-poll quantum in µs, bounded by the time until the next
    /// due arrival (the `Scheduler::next_arrival` discipline).
    pub idle_poll_us: u64,
    /// Requeue a dead replica's outstanding sequences onto survivors
    /// instead of failing the run (DESIGN.md §10).
    pub failover: bool,
    /// Chaos-injection schedule for the router-level fault domain
    /// (replica kills, keyed by admitted-request count). Engine-level
    /// faults live in `EngineConfig::faults`.
    pub faults: FaultPlan,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            policy: RoutePolicy::RoundRobin,
            shared_samplers: false,
            prefill_replicas: 0,
            kv_transfer_us_per_token: 2.0,
            idle_poll_us: 200,
            failover: true,
            faults: FaultPlan::default(),
        }
    }
}

impl ClusterConfig {
    /// CLI overrides: `--replicas N --route P --shared_samplers
    /// --prefill_replicas N --kv_transfer_us T --no_failover
    /// --chaos <spec>`.
    pub fn apply_args(&mut self, args: &Args) -> crate::Result<()> {
        self.replicas = args.get_or("replicas", self.replicas)?;
        if let Some(p) = args.get("route") {
            self.policy = RoutePolicy::parse(p)
                .ok_or_else(|| anyhow::anyhow!("unknown route policy {p}"))?;
        }
        if args.flag("shared_samplers") {
            self.shared_samplers = true;
        }
        self.prefill_replicas = args.get_or("prefill_replicas", self.prefill_replicas)?;
        self.kv_transfer_us_per_token =
            args.get_or("kv_transfer_us", self.kv_transfer_us_per_token)?;
        if args.flag("no_failover") {
            self.failover = false;
        }
        if let Some(spec) = args.get("chaos") {
            let (_engine, router_faults) = FaultPlan::parse(spec)?.split();
            self.faults = router_faults;
        }
        anyhow::ensure!(
            self.replicas >= 1,
            "--replicas must be at least 1 (got {})",
            self.replicas
        );
        anyhow::ensure!(
            self.prefill_replicas == 0 || self.prefill_replicas < self.replicas,
            "--prefill_replicas {} needs at least one decode replica \
             (--replicas {} — raise it)",
            self.prefill_replicas,
            self.replicas
        );
        Ok(())
    }
}

/// One replica's end-of-run view inside a [`ClusterReport`].
pub struct ReplicaSummary {
    pub id: usize,
    pub role: ReplicaRole,
    pub summary: ServingSummary,
    pub preemptions: u64,
}

/// Everything a drained cluster hands back: final sequences, the merged
/// fleet recorder (exact fleet-wide percentiles — see [`Recorder::merge`]),
/// per-replica summaries, and the decision plane's lifetime stats.
pub struct ClusterReport {
    pub finished: Vec<Sequence>,
    pub recorder: Recorder,
    pub per_replica: Vec<ReplicaSummary>,
    pub sampler_stats: Vec<SamplerStats>,
    pub preemptions: u64,
    /// Replica deaths the router failed over (each costs a requeue pass).
    pub failovers: u64,
    /// Sequences requeued onto survivors by those failovers.
    pub requeued: u64,
    /// Fleet-summed speculative-decoding tallies over committed windows.
    pub spec_accepted: u64,
    pub spec_proposed: u64,
    pub spec_committed: u64,
    pub spec_windows: u64,
    /// Fleet-summed prefill tokens computed vs skipped by prefix-cache
    /// hits (DESIGN.md §13) — `skipped / (computed + skipped)` is the
    /// fleet's prefill-reuse fraction.
    pub prefill_computed: u64,
    pub prefill_skipped: u64,
}

impl ClusterReport {
    /// The deterministic fleet stream digest — must equal a single-replica
    /// engine's digest for the same trace, whatever the routing (or the
    /// fault plan) did.
    pub fn stream_digest(&self) -> u64 {
        crate::util::stream_digest(
            self.finished
                .iter()
                .map(|s| (s.request.id, s.output.clone()))
                .collect(),
        )
    }
}

/// Block-aligned session key for [`RoutePolicy::SessionAffinity`]: the
/// digest of the prompt's first full KV block — the same chained digest
/// the engines' radix indexes are keyed by ([`kvcache::block_digests`]) —
/// so two prompts hash alike exactly when they could share a cached
/// block, and prompts diverging *inside* the first block hash apart.
/// Prompts shorter than one block fall back to FNV-1a over every token.
fn prefix_hash(prompt: &[u32], block_tokens: usize) -> u64 {
    if let Some(&d) = kvcache::block_digests(prompt, block_tokens).first() {
        return d;
    }
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in prompt {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Router-side *approximate* view of one replica's radix index
/// ([`RoutePolicy::PrefixCache`], DESIGN.md §13): the block digests of
/// every prompt dispatched there, FIFO-bounded so a long run cannot grow
/// it without bound, and cleared outright when the replica dies. It can
/// be stale — the replica may have evicted a block, or not have
/// materialized it yet — which only ever costs placement quality, never
/// correctness: hits and misses alike produce bit-identical streams.
struct PrefixIndex {
    set: HashSet<u64>,
    order: VecDeque<u64>,
    cap: usize,
}

impl PrefixIndex {
    fn new(cap: usize) -> PrefixIndex {
        PrefixIndex { set: HashSet::new(), order: VecDeque::new(), cap }
    }

    /// How many *leading* digests of `digests` this index holds — the
    /// router's estimate of the replica's longest cached prefix, in
    /// blocks. Prefix-consecutive by construction: a cached block is only
    /// useful if every block before it is cached too.
    fn match_len(&self, digests: &[u64]) -> usize {
        digests.iter().take_while(|d| self.set.contains(d)).count()
    }

    /// Record a dispatched prompt's digests, evicting oldest-first past
    /// the cap.
    fn observe(&mut self, digests: &[u64]) {
        for &d in digests {
            if self.set.insert(d) {
                self.order.push_back(d);
                if self.order.len() > self.cap {
                    if let Some(old) = self.order.pop_front() {
                        self.set.remove(&old);
                    }
                }
            }
        }
    }

    fn clear(&mut self) {
        self.set.clear();
        self.order.clear();
    }
}

/// Digests tracked per replica by the [`RoutePolicy::PrefixCache`] index
/// (FIFO-evicted beyond this).
const PREFIX_INDEX_CAP: usize = 4096;

/// Work the router has routed and not yet collected: everything needed to
/// replay the sequence on a survivor if its replica dies (`req` is the
/// request exactly as routed — the prefill-truncated copy in split mode —
/// and `output` the tokens it resumed with, empty for fresh submissions).
#[derive(Clone)]
struct RoutedEntry {
    replica: usize,
    role: ReplicaRole,
    req: Request,
    output: Vec<u32>,
}

/// A running fleet: replicas + the routing front-end.
pub struct Cluster {
    replicas: Vec<Replica>,
    cfg: ClusterConfig,
    pool: Option<Arc<SamplerService>>,
    t0: Instant,
    rr: usize,
    /// Original requests routed through the prefill pool, awaiting their
    /// first token; the handoff restores the real `max_new_tokens`.
    pending_handoff: HashMap<u64, Request>,
    /// In-flight work by request id — the failover sweep's replay source.
    routed: HashMap<u64, RoutedEntry>,
    /// KV block granularity (`EngineConfig::kv_block_tokens`) — the
    /// digest alignment shared with every replica's radix index.
    block_tokens: usize,
    /// Per-replica approximate prefix index for [`RoutePolicy::PrefixCache`].
    prefix_index: Vec<PrefixIndex>,
    /// Router-level chaos schedule (replica kills).
    faults: FaultPlan,
    failovers: u64,
    requeued: u64,
    failover_s: f64,
    finished: Vec<Sequence>,
    submitted: usize,
}

impl Cluster {
    /// Start `cfg.replicas` workers. Each data plane is built inside its
    /// worker thread by `make_plane(replica_id)`; every replica must load
    /// the *same* model (or the same synthetic-plane seed) — the routing
    /// invariant that keeps streams placement-independent. `pool_max_seq`
    /// sizes the shared pool's history caps (the planes' max_seq).
    pub fn start<D, F>(
        ecfg: &EngineConfig,
        ccfg: &ClusterConfig,
        hot: Option<Arc<HotVocab>>,
        pool_max_seq: usize,
        make_plane: F,
    ) -> Cluster
    where
        D: DataPlane + 'static,
        F: Fn(usize) -> crate::Result<D> + Send + Sync + 'static,
    {
        assert!(ccfg.replicas >= 1, "a cluster needs at least one replica");
        if ccfg.prefill_replicas > 0 {
            assert!(
                ccfg.prefill_replicas < ccfg.replicas,
                "the prefill/decode split needs at least one decode replica"
            );
        }
        // The router thread is lane (pid 0, main), and the fleet-wide t0 IS
        // the shared trace epoch — every replica and the pool adopt it.
        trace::register_thread(0, trace::TID_MAIN);
        let t0 = trace::epoch();
        let pool = ccfg.shared_samplers.then(|| {
            Arc::new(SamplerService::start_with_epoch(
                &ecfg.sampler,
                hot.clone(),
                pool_max_seq,
                t0,
            ))
        });
        let make = Arc::new(make_plane);
        let replicas = (0..ccfg.replicas)
            .map(|id| {
                let role = if ccfg.prefill_replicas == 0 {
                    ReplicaRole::Unified
                } else if id < ccfg.prefill_replicas {
                    ReplicaRole::Prefill
                } else {
                    ReplicaRole::Decode
                };
                let mk = make.clone();
                Replica::spawn(
                    id,
                    role,
                    ecfg.clone(),
                    hot.clone(),
                    pool.clone(),
                    t0,
                    move || mk(id),
                )
            })
            .collect();
        Cluster {
            replicas,
            cfg: ccfg.clone(),
            pool,
            t0,
            rr: 0,
            pending_handoff: HashMap::new(),
            routed: HashMap::new(),
            block_tokens: ecfg.kv_block_tokens,
            prefix_index: (0..ccfg.replicas)
                .map(|_| PrefixIndex::new(PREFIX_INDEX_CAP))
                .collect(),
            faults: ccfg.faults.clone(),
            failovers: 0,
            requeued: 0,
            failover_s: 0.0,
            finished: Vec::new(),
            submitted: 0,
        }
    }

    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Pick a surviving replica of `role` for `req` under the configured
    /// policy. Errors when every replica of that role is dead — the one
    /// failure failover cannot route around.
    fn pick(&mut self, req: &Request, role: ReplicaRole) -> crate::Result<usize> {
        let cands: Vec<usize> = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.role == role && !r.is_dead())
            .map(|(i, _)| i)
            .collect();
        anyhow::ensure!(
            !cands.is_empty(),
            "no surviving {} replica to route to",
            role.name()
        );
        Ok(match self.cfg.policy {
            RoutePolicy::RoundRobin => {
                let i = cands[self.rr % cands.len()];
                self.rr += 1;
                i
            }
            RoutePolicy::LeastOutstanding => *cands
                .iter()
                .min_by_key(|&&i| (self.replicas[i].outstanding(), i))
                .unwrap(),
            RoutePolicy::KvPressure => *cands
                .iter()
                .max_by_key(|&&i| {
                    // Free blocks NET of routed-but-unadmitted load (each
                    // outstanding sequence will take at least one block):
                    // a dispatch burst between heartbeats must not pile
                    // onto the replica whose heartbeat merely came first.
                    let r = &self.replicas[i];
                    (
                        r.kv_free_blocks().saturating_sub(r.outstanding()),
                        std::cmp::Reverse(r.outstanding()),
                        std::cmp::Reverse(i),
                    )
                })
                .unwrap(),
            RoutePolicy::SessionAffinity => {
                let h = prefix_hash(&req.prompt, self.block_tokens);
                cands[(h % cands.len() as u64) as usize]
            }
            RoutePolicy::PrefixCache => {
                let digests = kvcache::block_digests(&req.prompt, self.block_tokens);
                *cands
                    .iter()
                    .max_by_key(|&&i| {
                        // Longest estimated cached prefix wins; ties fall
                        // back to the KvPressure key so a cold fleet (or a
                        // cold prompt) degrades to load-aware placement
                        // instead of piling onto replica 0.
                        let r = &self.replicas[i];
                        (
                            self.prefix_index[i].match_len(&digests),
                            r.kv_free_blocks().saturating_sub(r.outstanding()),
                            std::cmp::Reverse(r.outstanding()),
                            std::cmp::Reverse(i),
                        )
                    })
                    .unwrap()
            }
        })
    }

    /// Route one unit of work (fresh when `output` is empty, a resume
    /// otherwise) to a surviving replica of `role`, recording it for the
    /// failover sweep.
    fn dispatch(
        &mut self,
        role: ReplicaRole,
        req: Request,
        output: Vec<u32>,
    ) -> crate::Result<()> {
        let i = self.pick(&req, role)?;
        if trace::on() {
            // The chosen replica's standing under the active policy's
            // scoring signal (f64 bits in `b`; decoded by the exporter).
            let score = match self.cfg.policy {
                RoutePolicy::RoundRobin => 0.0,
                RoutePolicy::LeastOutstanding => self.replicas[i].outstanding() as f64,
                RoutePolicy::KvPressure => self.replicas[i]
                    .kv_free_blocks()
                    .saturating_sub(self.replicas[i].outstanding())
                    as f64,
                RoutePolicy::SessionAffinity => {
                    prefix_hash(&req.prompt, self.block_tokens) as f64
                }
                RoutePolicy::PrefixCache => self.prefix_index[i]
                    .match_len(&kvcache::block_digests(&req.prompt, self.block_tokens))
                    as f64,
            };
            trace::instant(trace::Kind::RouteDecision, i as u64, score.to_bits());
        }
        if self.cfg.policy == RoutePolicy::PrefixCache {
            // The replica will materialize (or already holds) these blocks;
            // future prompts sharing the prefix should land with them.
            self.prefix_index[i]
                .observe(&kvcache::block_digests(&req.prompt, self.block_tokens));
        }
        self.routed.insert(
            req.id,
            RoutedEntry { replica: i, role, req: req.clone(), output: output.clone() },
        );
        if output.is_empty() {
            self.replicas[i].submit(req);
        } else {
            self.replicas[i].submit_resumed(req, output);
        }
        Ok(())
    }

    /// Admit one request into the fleet. In split mode a multi-token
    /// request first visits the prefill pool truncated to its first token.
    pub fn submit(&mut self, req: Request) -> crate::Result<()> {
        self.submitted += 1;
        // Chaos injection: replica kills keyed by admitted-request count.
        let due = self
            .faults
            .take_due(self.submitted as u64, |k| matches!(k, FaultKind::KillReplica { .. }));
        for kind in due {
            if let FaultKind::KillReplica { replica } = kind {
                if let Some(r) = self.replicas.get(replica) {
                    if !r.is_dead() {
                        r.inject_kill();
                    }
                }
            }
        }
        if self.cfg.prefill_replicas == 0 {
            self.dispatch(ReplicaRole::Unified, req, Vec::new())
        } else if req.max_new_tokens > 1 {
            let mut first = req.clone();
            first.max_new_tokens = 1;
            self.pending_handoff.insert(req.id, req);
            self.dispatch(ReplicaRole::Prefill, first, Vec::new())
        } else {
            // single-token request: the prefill pool is its whole lifecycle
            self.dispatch(ReplicaRole::Prefill, req, Vec::new())
        }
    }

    /// Drain every replica's outbox: collect final sequences and perform
    /// pending prefill→decode handoffs. Returns how many sequences were
    /// drained, so callers can skip the idle sleep while results flow.
    fn collect_finished(&mut self) -> crate::Result<usize> {
        let drained: Vec<Sequence> = self
            .replicas
            .iter()
            .flat_map(|r| r.drain_finished())
            .collect();
        let n = drained.len();
        for mut seq in drained {
            let id = seq.request.id;
            self.routed.remove(&id);
            let Some(orig) = self.pending_handoff.remove(&id) else {
                self.finished.push(seq);
                continue;
            };
            debug_assert_eq!(seq.output.len(), 1, "prefill pool emits one token");
            let hit_eos = orig
                .eos_token
                .is_some_and(|e| seq.output.last() == Some(&e));
            if hit_eos {
                // genuinely finished on its first token: no handoff;
                // restore the untruncated request for faithful reporting
                seq.request.max_new_tokens = orig.max_new_tokens;
                self.finished.push(seq);
            } else {
                // Handoff: the decode replica resumes with recompute
                // (decisions continue from iteration 1), admitted only
                // after the simulated KV-transfer delay has elapsed.
                let ctx = orig.prompt.len() + seq.output.len();
                let mut next = orig;
                next.arrival =
                    self.now() + ctx as f64 * self.cfg.kv_transfer_us_per_token * 1e-6;
                self.dispatch(ReplicaRole::Decode, next, seq.output)?;
            }
        }
        Ok(n)
    }

    /// Reap dead replicas and — with failover on — requeue their
    /// outstanding sequences onto survivors through the resume path. The
    /// requeued requests keep their original arrival stamps, so the
    /// recorder's latency percentiles absorb the recovery pause exactly.
    fn sweep_failures(&mut self) -> crate::Result<()> {
        let mut dead: Vec<(usize, String)> = Vec::new();
        for i in 0..self.replicas.len() {
            if let Some(msg) = self.replicas[i].try_reap_failure() {
                dead.push((i, msg));
            }
        }
        if dead.is_empty() {
            return Ok(());
        }
        if !self.cfg.failover {
            anyhow::bail!("{} (failover disabled)", dead[0].1);
        }
        let t0 = Instant::now();
        // Final sequences the corpses handed back before dying must be
        // collected first, or a finished sequence would be replayed.
        self.collect_finished()?;
        for (i, msg) in dead {
            eprintln!("[cluster] {msg}; requeueing its sequences onto survivors");
            // A dead replica's cache died with it: stop steering prefix
            // traffic at the corpse's ghost index.
            self.prefix_index[i].clear();
            if let Some(pool) = &self.pool {
                // Drop the dead replica's in-flight decision state: its
                // pending partial collects and retained tasks, and any
                // stale batches still in flight for its namespace — the
                // requeue below re-registers the sequences with replay.
                pool.purge_namespace(self.replicas[i].task_namespace());
            }
            let mut orphans: Vec<(u64, RoutedEntry)> = self
                .routed
                .iter()
                .filter(|(_, e)| e.replica == i)
                .map(|(&id, e)| (id, e.clone()))
                .collect();
            orphans.sort_unstable_by_key(|&(id, _)| id);
            for (id, e) in orphans {
                self.routed.remove(&id);
                self.requeued += 1;
                trace::metrics::inc(&trace::metrics::counters().router_requeues);
                trace::instant(trace::Kind::RouteRequeue, id, i as u64);
                self.dispatch(e.role, e.req, e.output)?;
            }
            self.failovers += 1;
            trace::metrics::inc(&trace::metrics::counters().failovers);
        }
        self.failover_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Total requests still in flight anywhere in the fleet.
    pub fn inflight(&self) -> usize {
        self.submitted - self.finished.len()
    }

    /// Bounded idle poll: sleep at most `idle_poll_us`, clipped to the
    /// time until `next_arrival` when one is pending — and not at all when
    /// it is already due (`None` = sleep the full quantum). The same
    /// discipline as `Scheduler::next_arrival`, shared by the run loop and
    /// the shutdown drain so neither inflates drain latency by a full poll
    /// interval.
    fn bounded_poll(&self, next_arrival: Option<f64>) {
        let quantum = self.cfg.idle_poll_us.max(1);
        let poll_us = match next_arrival {
            Some(t) => {
                let until_us = ((t - self.now()) * 1e6).ceil();
                if until_us <= 0.0 {
                    return; // due now: continue immediately
                }
                quantum.min(until_us as u64).max(1)
            }
            None => quantum,
        };
        std::thread::sleep(std::time::Duration::from_micros(poll_us));
    }

    /// Dispatch a trace open-loop — each request fires at its `arrival`
    /// stamp against the cluster epoch — and drain the fleet. The idle
    /// loop is `Scheduler::next_arrival`-style bounded polling (see
    /// [`Self::bounded_poll`]), and skips the sleep entirely on any pass
    /// that drained a finished sequence. Returns once every request's
    /// final sequence has been collected (handoffs and failover requeues
    /// included).
    pub fn run(&mut self, mut requests: Vec<Request>) -> crate::Result<()> {
        requests.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        let mut queue: VecDeque<Request> = requests.into();
        loop {
            let now = self.now();
            while queue.front().is_some_and(|r| r.arrival <= now) {
                let r = queue.pop_front().unwrap();
                self.submit(r)?;
            }
            let drained = self.collect_finished()?;
            if queue.is_empty() && self.inflight() == 0 {
                debug_assert!(self.pending_handoff.is_empty());
                return Ok(());
            }
            self.sweep_failures()?;
            if drained > 0 {
                continue; // results are flowing: re-check without sleeping
            }
            self.bounded_poll(queue.front().map(|r| r.arrival));
        }
    }

    /// Drain whatever is still in flight, stop every replica, join the
    /// workers, and assemble the fleet report. The stop is only requested
    /// *after* the last final sequence is collected, so join-on-shutdown
    /// can never lose an in-flight, handed-off, or requeued sequence.
    pub fn shutdown(mut self) -> crate::Result<ClusterReport> {
        // A corpse may postdate run()'s last sweep — a kill landing on an
        // already-idle replica leaves inflight at 0, so neither run() nor
        // the drain loop below would reap it. Sweep once up front, while
        // `stop` is still unset (try_reap_failure ignores post-stop exits).
        self.sweep_failures()?;
        while self.inflight() > 0 {
            let drained = self.collect_finished()?;
            if self.inflight() == 0 {
                break;
            }
            self.sweep_failures()?;
            if drained == 0 {
                // same bounded discipline as the run loop (no pending
                // arrivals here — sleep at most one quantum, and only
                // when no results flowed this pass)
                self.bounded_poll(None);
            }
        }
        for r in &self.replicas {
            r.request_stop();
        }
        let failover = self.cfg.failover;
        let mut late_failovers = 0u64;
        let mut merged = Recorder::new();
        let mut per_replica = Vec::new();
        let mut sampler_stats = Vec::new();
        let mut preemptions = 0u64;
        let mut spec = [0u64; 4];
        let mut prefill = [0u64; 2];
        for r in self.replicas.drain(..) {
            if r.is_dead() {
                // reaped after a failure: its partial recorder died with
                // it; its requeued sequences' lifecycles were recorded in
                // full by the survivors that replayed them
                continue;
            }
            let (id, role) = (r.id, r.role);
            let res = match r.join() {
                Ok(res) => res,
                Err(e) if failover => {
                    // died in the sweep→stop window: every final sequence
                    // is already collected (inflight is 0), so the death
                    // costs only this replica's partial recorder
                    eprintln!("[cluster] replica {id} died at shutdown ({e:#})");
                    late_failovers += 1;
                    continue;
                }
                Err(e) => return Err(e),
            };
            merged.merge(&res.recorder);
            preemptions += res.preemptions;
            spec[0] += res.spec_accepted;
            spec[1] += res.spec_proposed;
            spec[2] += res.spec_committed;
            spec[3] += res.spec_windows;
            prefill[0] += res.prefill_computed;
            prefill[1] += res.prefill_skipped;
            sampler_stats.extend(res.sampler_stats);
            per_replica.push(ReplicaSummary {
                id,
                role,
                summary: res.recorder.summary(),
                preemptions: res.preemptions,
            });
        }
        self.failovers += late_failovers;
        if let Some(pool) = self.pool.take() {
            match Arc::try_unwrap(pool) {
                // shared mode: the pool holds the fleet's only sampler
                // stats and its sampler-level recovery accounting
                Ok(svc) => {
                    let rec = svc.recovery_stats();
                    merged.on_recovery(rec.respawns, rec.recovery_s);
                    sampler_stats = svc.shutdown();
                }
                Err(_) => anyhow::bail!("shared sampler pool still referenced at shutdown"),
            }
        }
        merged.on_recovery(self.failovers, self.failover_s);
        Ok(ClusterReport {
            finished: std::mem::take(&mut self.finished),
            recorder: merged,
            per_replica,
            sampler_stats,
            preemptions,
            failovers: self.failovers,
            requeued: self.requeued,
            spec_accepted: spec[0],
            spec_proposed: spec[1],
            spec_committed: spec[2],
            spec_windows: spec[3],
            prefill_computed: prefill[0],
            prefill_skipped: prefill[1],
        })
    }
}

impl Drop for Cluster {
    /// A cluster dropped without `shutdown()` (an error path) at least
    /// unblocks its workers: they exit as soon as their engines drain.
    fn drop(&mut self) {
        for r in &self.replicas {
            r.request_stop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BT: usize = 16;

    #[test]
    fn prefix_hash_is_block_aligned() {
        let shared: Vec<u32> = (100..100 + BT as u32).collect();
        // Same first block, different tails → same session key.
        let mut a = shared.clone();
        a.extend([1, 2, 3]);
        let mut b = shared.clone();
        b.extend([7, 8]);
        assert_eq!(prefix_hash(&a, BT), prefix_hash(&b, BT));
        // Divergence INSIDE the first block → different key, even though
        // the first 8 tokens (the old hash's window) still agree.
        let mut c = shared.clone();
        c[BT - 4] ^= 1;
        assert_ne!(prefix_hash(&a, BT), prefix_hash(&c, BT));
        // The key is the radix index's own digest for that block.
        assert_eq!(prefix_hash(&a, BT), kvcache::block_digests(&shared, BT)[0]);
    }

    #[test]
    fn prefix_hash_short_prompt_falls_back_to_full_fnv() {
        assert_eq!(prefix_hash(&[1, 2, 3], BT), prefix_hash(&[1, 2, 3], BT));
        assert_ne!(prefix_hash(&[1, 2, 3], BT), prefix_hash(&[1, 2, 4], BT));
    }

    #[test]
    fn prefix_index_scores_longest_leading_match() {
        let prompt: Vec<u32> = (0..3 * BT as u32).collect();
        let digests = kvcache::block_digests(&prompt, BT);
        assert_eq!(digests.len(), 3);
        let mut idx = PrefixIndex::new(64);
        assert_eq!(idx.match_len(&digests), 0);
        idx.observe(&digests[..2]);
        assert_eq!(idx.match_len(&digests), 2);
        // A hole at block 0 voids the deeper match: scoring is
        // prefix-consecutive, not set-intersection.
        let mut holes = PrefixIndex::new(64);
        holes.observe(&digests[1..]);
        assert_eq!(holes.match_len(&digests), 0);
    }

    #[test]
    fn prefix_index_evicts_fifo_past_cap_and_clears() {
        let mut idx = PrefixIndex::new(2);
        idx.observe(&[10, 20, 30]); // 10 evicted by 30
        assert!(!idx.set.contains(&10));
        assert!(idx.set.contains(&20) && idx.set.contains(&30));
        idx.observe(&[20]); // already present: no-op, no double entry
        assert_eq!(idx.order.len(), 2);
        idx.clear();
        assert_eq!(idx.match_len(&[20, 30]), 0);
    }
}
