//! The cluster front-end: a decision-plane-aware router admitting requests
//! into data-parallel engine replicas (DESIGN.md §9).
//!
//! Four pluggable [`RoutePolicy`]s: `RoundRobin` (placement-blind),
//! `LeastOutstanding` (queue depth from replica heartbeats),
//! `KvPressure` (live KV-block occupancy — the llm-d-style load signal
//! that diverts traffic from a cache-saturated replica *before* it starts
//! preempting), and `SessionAffinity` (prompt-prefix hash, so
//! shared-prefix traffic lands on the replica whose cache already holds
//! the prefix's working set).
//!
//! Routing moves work, never decisions: per-sequence token streams are
//! bit-identical to a single-replica engine for every policy, replica
//! count, sampler count, `spec_k`, and `n_microbatches`
//! (`proptests.rs::prop_routed_streams_equal_single_replica`).
//!
//! With `shared_samplers` the router owns one [`SamplerService`] pool that
//! every replica submits into (task ids namespaced per replica), pooling
//! decision-plane capacity instead of stranding it per replica. With
//! `prefill_replicas > 0` the fleet splits DistServe-style: prefill
//! replicas serve each request truncated to its first token, then the
//! router hands the sequence to a decode replica with a simulated
//! KV-transfer delay (`kv_transfer_us_per_token × context`), realized as
//! the resumed request's arrival time.

use super::replica::{Replica, ReplicaRole};
use crate::config::EngineConfig;
use crate::decision::service::{SamplerService, SamplerStats};
use crate::decision::HotVocab;
use crate::engine::{DataPlane, Request, Sequence};
use crate::metrics::{Recorder, ServingSummary};
use crate::util::argparse::Args;
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

/// Replica-selection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through the candidates — placement-blind baseline.
    RoundRobin,
    /// Fewest routed-but-unfinished sequences (inbox + engine depth).
    LeastOutstanding,
    /// Most free KV blocks in the latest heartbeat, net of
    /// routed-but-unadmitted load (ties: fewest outstanding, then lowest
    /// id) — diverts from cache-saturated replicas before they preempt.
    KvPressure,
    /// Prompt-prefix hash, so shared-prefix sessions co-locate.
    SessionAffinity,
}

impl RoutePolicy {
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        Some(match s.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => Self::RoundRobin,
            "lo" | "least" | "least-outstanding" => Self::LeastOutstanding,
            "kv" | "kv-pressure" | "kvpressure" => Self::KvPressure,
            "affinity" | "session" | "session-affinity" => Self::SessionAffinity,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::RoundRobin => "round-robin",
            Self::LeastOutstanding => "least-outstanding",
            Self::KvPressure => "kv-pressure",
            Self::SessionAffinity => "session-affinity",
        }
    }

    pub const ALL: [RoutePolicy; 4] = [
        Self::RoundRobin,
        Self::LeastOutstanding,
        Self::KvPressure,
        Self::SessionAffinity,
    ];
}

/// Cluster-layer configuration (the engine-layer knobs stay in
/// [`EngineConfig`]; every replica gets a clone of it).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Data-parallel engine replicas.
    pub replicas: usize,
    pub policy: RoutePolicy,
    /// One shared sampler pool for the whole fleet instead of
    /// `replicas × num_samplers` stranded per-replica workers.
    pub shared_samplers: bool,
    /// DistServe-style split: this many replicas serve prefill only and
    /// hand sequences to the remaining decode replicas (0 = unified).
    pub prefill_replicas: usize,
    /// Simulated KV-transfer cost per context token for the prefill→decode
    /// handoff, in microseconds (the decode arrival is delayed by
    /// `context × this`).
    pub kv_transfer_us_per_token: f64,
    /// Router idle-poll quantum in µs, bounded by the time until the next
    /// due arrival (the `Scheduler::next_arrival` discipline).
    pub idle_poll_us: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            policy: RoutePolicy::RoundRobin,
            shared_samplers: false,
            prefill_replicas: 0,
            kv_transfer_us_per_token: 2.0,
            idle_poll_us: 200,
        }
    }
}

impl ClusterConfig {
    /// CLI overrides: `--replicas N --route P --shared_samplers
    /// --prefill_replicas N --kv_transfer_us T`.
    pub fn apply_args(&mut self, args: &Args) -> crate::Result<()> {
        self.replicas = args.get_or("replicas", self.replicas)?;
        if let Some(p) = args.get("route") {
            self.policy = RoutePolicy::parse(p)
                .ok_or_else(|| anyhow::anyhow!("unknown route policy {p}"))?;
        }
        if args.flag("shared_samplers") {
            self.shared_samplers = true;
        }
        self.prefill_replicas = args.get_or("prefill_replicas", self.prefill_replicas)?;
        self.kv_transfer_us_per_token =
            args.get_or("kv_transfer_us", self.kv_transfer_us_per_token)?;
        anyhow::ensure!(
            self.replicas >= 1,
            "--replicas must be at least 1 (got {})",
            self.replicas
        );
        anyhow::ensure!(
            self.prefill_replicas == 0 || self.prefill_replicas < self.replicas,
            "--prefill_replicas {} needs at least one decode replica \
             (--replicas {} — raise it)",
            self.prefill_replicas,
            self.replicas
        );
        Ok(())
    }
}

/// One replica's end-of-run view inside a [`ClusterReport`].
pub struct ReplicaSummary {
    pub id: usize,
    pub role: ReplicaRole,
    pub summary: ServingSummary,
    pub preemptions: u64,
}

/// Everything a drained cluster hands back: final sequences, the merged
/// fleet recorder (exact fleet-wide percentiles — see [`Recorder::merge`]),
/// per-replica summaries, and the decision plane's lifetime stats.
pub struct ClusterReport {
    pub finished: Vec<Sequence>,
    pub recorder: Recorder,
    pub per_replica: Vec<ReplicaSummary>,
    pub sampler_stats: Vec<SamplerStats>,
    pub preemptions: u64,
    /// Fleet-summed speculative-decoding tallies over committed windows.
    pub spec_accepted: u64,
    pub spec_proposed: u64,
    pub spec_committed: u64,
    pub spec_windows: u64,
}

impl ClusterReport {
    /// The deterministic fleet stream digest — must equal a single-replica
    /// engine's digest for the same trace, whatever the routing did.
    pub fn stream_digest(&self) -> u64 {
        crate::util::stream_digest(
            self.finished
                .iter()
                .map(|s| (s.request.id, s.output.clone()))
                .collect(),
        )
    }
}

/// FNV-1a over the first 8 prompt tokens — the session key for
/// [`RoutePolicy::SessionAffinity`] (shared-prefix traffic hashes alike).
fn prefix_hash(prompt: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in prompt.iter().take(8) {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// A running fleet: replicas + the routing front-end.
pub struct Cluster {
    replicas: Vec<Replica>,
    cfg: ClusterConfig,
    pool: Option<Arc<SamplerService>>,
    t0: Instant,
    rr: usize,
    /// Original requests routed through the prefill pool, awaiting their
    /// first token; the handoff restores the real `max_new_tokens`.
    pending_handoff: HashMap<u64, Request>,
    finished: Vec<Sequence>,
    submitted: usize,
}

impl Cluster {
    /// Start `cfg.replicas` workers. Each data plane is built inside its
    /// worker thread by `make_plane(replica_id)`; every replica must load
    /// the *same* model (or the same synthetic-plane seed) — the routing
    /// invariant that keeps streams placement-independent. `pool_max_seq`
    /// sizes the shared pool's history caps (the planes' max_seq).
    pub fn start<D, F>(
        ecfg: &EngineConfig,
        ccfg: &ClusterConfig,
        hot: Option<Arc<HotVocab>>,
        pool_max_seq: usize,
        make_plane: F,
    ) -> Cluster
    where
        D: DataPlane + 'static,
        F: Fn(usize) -> crate::Result<D> + Send + Sync + 'static,
    {
        assert!(ccfg.replicas >= 1, "a cluster needs at least one replica");
        if ccfg.prefill_replicas > 0 {
            assert!(
                ccfg.prefill_replicas < ccfg.replicas,
                "the prefill/decode split needs at least one decode replica"
            );
        }
        let t0 = Instant::now();
        let pool = ccfg.shared_samplers.then(|| {
            Arc::new(SamplerService::start_with_epoch(
                &ecfg.sampler,
                hot.clone(),
                pool_max_seq,
                t0,
            ))
        });
        let make = Arc::new(make_plane);
        let replicas = (0..ccfg.replicas)
            .map(|id| {
                let role = if ccfg.prefill_replicas == 0 {
                    ReplicaRole::Unified
                } else if id < ccfg.prefill_replicas {
                    ReplicaRole::Prefill
                } else {
                    ReplicaRole::Decode
                };
                let mk = make.clone();
                Replica::spawn(
                    id,
                    role,
                    ecfg.clone(),
                    hot.clone(),
                    pool.clone(),
                    t0,
                    move || mk(id),
                )
            })
            .collect();
        Cluster {
            replicas,
            cfg: ccfg.clone(),
            pool,
            t0,
            rr: 0,
            pending_handoff: HashMap::new(),
            finished: Vec::new(),
            submitted: 0,
        }
    }

    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Pick a replica of `role` for `req` under the configured policy.
    fn pick(&mut self, req: &Request, role: ReplicaRole) -> usize {
        let cands: Vec<usize> = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.role == role)
            .map(|(i, _)| i)
            .collect();
        debug_assert!(!cands.is_empty(), "no {} replica", role.name());
        match self.cfg.policy {
            RoutePolicy::RoundRobin => {
                let i = cands[self.rr % cands.len()];
                self.rr += 1;
                i
            }
            RoutePolicy::LeastOutstanding => *cands
                .iter()
                .min_by_key(|&&i| (self.replicas[i].outstanding(), i))
                .unwrap(),
            RoutePolicy::KvPressure => *cands
                .iter()
                .max_by_key(|&&i| {
                    // Free blocks NET of routed-but-unadmitted load (each
                    // outstanding sequence will take at least one block):
                    // a dispatch burst between heartbeats must not pile
                    // onto the replica whose heartbeat merely came first.
                    let r = &self.replicas[i];
                    (
                        r.kv_free_blocks().saturating_sub(r.outstanding()),
                        std::cmp::Reverse(r.outstanding()),
                        std::cmp::Reverse(i),
                    )
                })
                .unwrap(),
            RoutePolicy::SessionAffinity => {
                cands[(prefix_hash(&req.prompt) % cands.len() as u64) as usize]
            }
        }
    }

    /// Admit one request into the fleet. In split mode a multi-token
    /// request first visits the prefill pool truncated to its first token.
    pub fn submit(&mut self, req: Request) {
        self.submitted += 1;
        if self.cfg.prefill_replicas == 0 {
            let i = self.pick(&req, ReplicaRole::Unified);
            self.replicas[i].submit(req);
        } else if req.max_new_tokens > 1 {
            let mut first = req.clone();
            first.max_new_tokens = 1;
            self.pending_handoff.insert(req.id, req);
            let i = self.pick(&first, ReplicaRole::Prefill);
            self.replicas[i].submit(first);
        } else {
            // single-token request: the prefill pool is its whole lifecycle
            let i = self.pick(&req, ReplicaRole::Prefill);
            self.replicas[i].submit(req);
        }
    }

    /// Drain every replica's outbox: collect final sequences and perform
    /// pending prefill→decode handoffs.
    fn collect_finished(&mut self) {
        let drained: Vec<Sequence> = self
            .replicas
            .iter()
            .flat_map(|r| r.drain_finished())
            .collect();
        for mut seq in drained {
            let id = seq.request.id;
            let Some(orig) = self.pending_handoff.remove(&id) else {
                self.finished.push(seq);
                continue;
            };
            debug_assert_eq!(seq.output.len(), 1, "prefill pool emits one token");
            let hit_eos = orig
                .eos_token
                .is_some_and(|e| seq.output.last() == Some(&e));
            if hit_eos {
                // genuinely finished on its first token: no handoff;
                // restore the untruncated request for faithful reporting
                seq.request.max_new_tokens = orig.max_new_tokens;
                self.finished.push(seq);
            } else {
                // Handoff: the decode replica resumes with recompute
                // (decisions continue from iteration 1), admitted only
                // after the simulated KV-transfer delay has elapsed.
                let ctx = orig.prompt.len() + seq.output.len();
                let mut next = orig;
                next.arrival =
                    self.now() + ctx as f64 * self.cfg.kv_transfer_us_per_token * 1e-6;
                let d = self.pick(&next, ReplicaRole::Decode);
                self.replicas[d].submit_resumed(next, seq.output);
            }
        }
    }

    /// Total requests still in flight anywhere in the fleet.
    pub fn inflight(&self) -> usize {
        self.submitted - self.finished.len()
    }

    /// Dispatch a trace open-loop — each request fires at its `arrival`
    /// stamp against the cluster epoch — and drain the fleet. The idle
    /// loop is `Scheduler::next_arrival`-style bounded polling: sleep at
    /// most `idle_poll_us`, clipped to the time until the next due
    /// arrival, and not at all when one is already due. Returns once every
    /// request's final sequence has been collected (handoffs included).
    pub fn run(&mut self, mut requests: Vec<Request>) -> crate::Result<()> {
        requests.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        let mut queue: VecDeque<Request> = requests.into();
        loop {
            let now = self.now();
            while queue.front().is_some_and(|r| r.arrival <= now) {
                let r = queue.pop_front().unwrap();
                self.submit(r);
            }
            self.collect_finished();
            if queue.is_empty() && self.inflight() == 0 {
                debug_assert!(self.pending_handoff.is_empty());
                return Ok(());
            }
            for r in &mut self.replicas {
                r.check_alive()?;
            }
            let poll_us = match queue.front() {
                Some(r) => {
                    let until_us = ((r.arrival - self.now()) * 1e6).ceil();
                    if until_us <= 0.0 {
                        continue; // due now: dispatch immediately
                    }
                    self.cfg.idle_poll_us.min(until_us as u64).max(1)
                }
                None => self.cfg.idle_poll_us.max(1),
            };
            std::thread::sleep(std::time::Duration::from_micros(poll_us));
        }
    }

    /// Drain whatever is still in flight, stop every replica, join the
    /// workers, and assemble the fleet report. The stop is only requested
    /// *after* the last final sequence is collected, so join-on-shutdown
    /// can never lose an in-flight or handed-off sequence.
    pub fn shutdown(mut self) -> crate::Result<ClusterReport> {
        while self.inflight() > 0 {
            self.collect_finished();
            if self.inflight() == 0 {
                break;
            }
            for r in &mut self.replicas {
                r.check_alive()?;
            }
            std::thread::sleep(std::time::Duration::from_micros(
                self.cfg.idle_poll_us.max(1),
            ));
        }
        for r in &self.replicas {
            r.request_stop();
        }
        let mut merged = Recorder::new();
        let mut per_replica = Vec::new();
        let mut sampler_stats = Vec::new();
        let mut preemptions = 0u64;
        let mut spec = [0u64; 4];
        for r in self.replicas.drain(..) {
            let (id, role) = (r.id, r.role);
            let res = r.join()?;
            merged.merge(&res.recorder);
            preemptions += res.preemptions;
            spec[0] += res.spec_accepted;
            spec[1] += res.spec_proposed;
            spec[2] += res.spec_committed;
            spec[3] += res.spec_windows;
            sampler_stats.extend(res.sampler_stats);
            per_replica.push(ReplicaSummary {
                id,
                role,
                summary: res.recorder.summary(),
                preemptions: res.preemptions,
            });
        }
        if let Some(pool) = self.pool.take() {
            match Arc::try_unwrap(pool) {
                // shared mode: the pool holds the fleet's only sampler stats
                Ok(svc) => sampler_stats = svc.shutdown(),
                Err(_) => anyhow::bail!("shared sampler pool still referenced at shutdown"),
            }
        }
        Ok(ClusterReport {
            finished: std::mem::take(&mut self.finished),
            recorder: merged,
            per_replica,
            sampler_stats,
            preemptions,
            spec_accepted: spec[0],
            spec_proposed: spec[1],
            spec_committed: spec[2],
            spec_windows: spec[3],
        })
    }
}

impl Drop for Cluster {
    /// A cluster dropped without `shutdown()` (an error path) at least
    /// unblocks its workers: they exit as soon as their engines drain.
    fn drop(&mut self) {
        for r in &self.replicas {
            r.request_stop();
        }
    }
}
