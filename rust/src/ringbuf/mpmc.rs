//! Bounded lock-free MPMC ring (Vyukov bounded queue).
//!
//! The decision plane's sharded task queues: every sampler worker owns one
//! ring, the engine (or several engine replicas sharing one pool) pushes
//! into it concurrently, and *any* worker may pop from it — the owner on
//! its fast path, siblings when they steal. Per-slot sequence numbers
//! carry the synchronization, so neither push nor pop ever takes a lock:
//! a push claims a slot by CAS on the head counter and publishes the value
//! with a release store of the slot's sequence; a pop claims by CAS on the
//! tail and retires the slot one lap ahead. Contended operations retry on
//! a fresh counter read instead of blocking.
//!
//! Compared with the [`super::spsc`] ring (exactly one producer, one
//! consumer, used for the logits data path), this ring trades two CAS
//! loops for full MPMC freedom — which is exactly what work stealing and
//! multi-replica submission need.
//!
//! Model-checked: `rust/tests/loom_models.rs` runs producer races, steal
//! races, wraparound, and close/drain on this exact type (`make loom`).

use crate::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::util::sync::cell::UnsafeCell;
use crate::util::sync::{hint, thread, Arc};
use std::mem::MaybeUninit;

/// Pad to a cache line to avoid false sharing between the head and tail
/// counters (crossbeam's CachePadded, hand-rolled).
#[repr(align(64))]
struct CachePadded<T>(T);

struct Slot<T> {
    /// Lap sequence: `pos` = empty and writable for the push at `pos`;
    /// `pos + 1` = full and readable for the pop at `pos`; `pos + cap` =
    /// empty again one lap later.
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

struct Inner<T> {
    slots: Box<[Slot<T>]>,
    mask: usize,
    /// Next enqueue position (monotonic; slot = pos & mask).
    head: CachePadded<AtomicUsize>,
    /// Next dequeue position.
    tail: CachePadded<AtomicUsize>,
    closed: AtomicBool,
}

// SAFETY: the per-slot `seq` protocol hands each `val` cell to exactly one
// thread at a time (a push owns it between its head-CAS and its seq
// release store; a pop between its tail-CAS and its retire store), so the
// ring is Sync whenever the payload can be sent between threads.
unsafe impl<T: Send> Send for Inner<T> {}
// SAFETY: as above — cell access is serialized by the seq protocol.
unsafe impl<T: Send> Sync for Inner<T> {}

/// Cloneable handle; every clone may both push and pop.
pub struct Ring<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for Ring<T> {
    fn clone(&self) -> Self {
        Ring { inner: self.inner.clone() }
    }
}

/// Error returned by [`Ring::try_push`], handing the item back.
#[derive(Debug)]
pub enum PushError<T> {
    /// Ring at capacity.
    Full(T),
    /// Ring closed; no further items are accepted.
    Closed(T),
}

/// Error returned by [`Ring::try_pop`] on an empty ring.
#[derive(Debug, PartialEq, Eq)]
pub enum PopError {
    Empty,
    /// Closed *and* drained.
    Closed,
}

impl<T> Ring<T> {
    /// Create a ring of capacity `cap` (rounded up to a power of two).
    pub fn new(cap: usize) -> Ring<T> {
        let cap = cap.max(2).next_power_of_two();
        let slots: Box<[Slot<T>]> = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        Ring {
            inner: Arc::new(Inner {
                slots,
                mask: cap - 1,
                head: CachePadded(AtomicUsize::new(0)),
                tail: CachePadded(AtomicUsize::new(0)),
                closed: AtomicBool::new(false),
            }),
        }
    }

    /// Non-blocking push.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let inner = &*self.inner;
        if inner.closed.load(Ordering::Acquire) {
            return Err(PushError::Closed(item));
        }
        let mut pos = inner.head.0.load(Ordering::Relaxed);
        loop {
            let slot = &inner.slots[pos & inner.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                // Slot empty for this lap: claim it by advancing head.
                // ordering: Relaxed on the head CAS is sound — head is
                // only a ticket counter; the slot's seq (Acquire above,
                // Release below) carries all data synchronization.
                match inner.head.0.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the head CAS made this thread the sole
                        // owner of slot `pos` until the seq store below
                        // publishes it; no reader touches the cell while
                        // seq == pos.
                        slot.val.with_mut(|p| unsafe { (*p).write(item) });
                        slot.seq.store(pos + 1, Ordering::Release);
                        return Ok(());
                    }
                    Err(p) => pos = p,
                }
            } else if diff < 0 {
                // Slot still holds last lap's value: ring full.
                return Err(PushError::Full(item));
            } else {
                // Another producer claimed `pos`; chase the head.
                pos = inner.head.0.load(Ordering::Relaxed);
            }
        }
    }

    /// Spin-then-yield blocking push. Returns `false` (item dropped) if the
    /// ring is closed.
    pub fn push(&self, mut item: T) -> bool {
        let mut spins = 0u32;
        loop {
            match self.try_push(item) {
                Ok(()) => return true,
                Err(PushError::Closed(_)) => return false,
                Err(PushError::Full(back)) => {
                    item = back;
                    spins += 1;
                    if spins < 64 {
                        hint::spin_loop();
                    } else {
                        thread::yield_now();
                    }
                }
            }
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Result<T, PopError> {
        let inner = &*self.inner;
        let mut pos = inner.tail.0.load(Ordering::Relaxed);
        loop {
            let slot = &inner.slots[pos & inner.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - (pos + 1) as isize;
            if diff == 0 {
                // Slot full for this lap: claim it by advancing tail.
                // ordering: Relaxed on the tail CAS is sound — tail is
                // only a ticket counter; the slot's seq (Acquire above,
                // Release below) carries all data synchronization.
                match inner.tail.0.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the tail CAS made this thread the sole
                        // owner of slot `pos`; the Acquire seq load saw
                        // the producer's publication, so the value is
                        // fully written, and no other thread touches the
                        // cell until the retire store below.
                        let item =
                            slot.val.with_mut(|p| unsafe { (*p).assume_init_read() });
                        // Retire the slot for the push one lap ahead.
                        slot.seq.store(pos + inner.mask + 1, Ordering::Release);
                        return Ok(item);
                    }
                    Err(p) => pos = p,
                }
            } else if diff < 0 {
                // Nothing published at `pos` yet. A closed ring is only
                // *drained* once no push has claimed past us (an in-flight
                // push that claimed before the close still gets delivered).
                return if inner.closed.load(Ordering::Acquire)
                    && inner.head.0.load(Ordering::Acquire) == pos
                {
                    Err(PopError::Closed)
                } else {
                    Err(PopError::Empty)
                };
            } else {
                // Another consumer claimed `pos`; chase the tail.
                pos = inner.tail.0.load(Ordering::Relaxed);
            }
        }
    }

    /// Spin-then-yield blocking pop; `None` once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut spins = 0u32;
        loop {
            match self.try_pop() {
                Ok(item) => return Some(item),
                Err(PopError::Closed) => return None,
                Err(PopError::Empty) => {
                    spins += 1;
                    if spins < 64 {
                        hint::spin_loop();
                    } else {
                        thread::yield_now();
                    }
                }
            }
        }
    }

    /// Mark the ring closed: pushes fail from here on, pops drain what is
    /// left and then report [`PopError::Closed`].
    pub fn close(&self) {
        self.inner.closed.store(true, Ordering::Release);
    }

    pub fn is_closed(&self) -> bool {
        self.inner.closed.load(Ordering::Acquire)
    }

    /// Approximate queued-item count (exact when quiescent).
    pub fn len(&self) -> usize {
        let head = self.inner.head.0.load(Ordering::Relaxed);
        let tail = self.inner.tail.0.load(Ordering::Relaxed);
        head.saturating_sub(tail)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.inner.mask + 1
    }
}

impl<T> Drop for Inner<T> {
    fn drop(&mut self) {
        // Sole owner at this point: drain still-published slots so T's
        // Drop runs (leak check covered in tests). Plain loads suffice —
        // `&mut self` proves every other handle is gone, and the final
        // refcount decrement that got us here is an acquire edge.
        let mask = self.mask;
        let mut pos = self.tail.0.load(Ordering::Relaxed);
        let head = self.head.0.load(Ordering::Relaxed);
        while pos != head {
            let slot = &self.slots[pos & mask];
            if slot.seq.load(Ordering::Relaxed) == pos + 1 {
                // SAFETY: slot `pos` was published and never popped, and
                // `&mut self` makes this access exclusive.
                slot.val.with_mut(|p| unsafe { (*p).assume_init_drop() });
            }
            pos += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::thread;

    #[test]
    fn fifo_single_thread() {
        let r = Ring::<u32>::new(4);
        for i in 0..4 {
            r.try_push(i).unwrap();
        }
        for i in 0..4 {
            assert_eq!(r.try_pop().unwrap(), i);
        }
        assert_eq!(r.try_pop(), Err(PopError::Empty));
    }

    #[test]
    fn full_ring_backpressure() {
        let r = Ring::<u32>::new(4);
        for i in 0..4 {
            r.try_push(i).unwrap();
        }
        assert!(matches!(r.try_push(99), Err(PushError::Full(99))));
        assert_eq!(r.len(), 4);
        // Blocking push unblocks exactly when a pop frees a slot.
        let r2 = r.clone();
        let pusher = thread::spawn(move || r2.push(4));
        thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(r.try_pop().unwrap(), 0);
        assert!(pusher.join().unwrap());
        let rest: Vec<u32> = std::iter::from_fn(|| r.try_pop().ok()).collect();
        assert_eq!(rest, vec![1, 2, 3, 4]);
    }

    #[test]
    fn wraparound_at_capacity_boundaries() {
        // Repeatedly cross the wrap point with a fill level that is not a
        // divisor of the capacity, so every slot sees many laps and the
        // lap-sequence arithmetic is exercised on both sides of the seam.
        let r = Ring::<usize>::new(4);
        let mut next_push = 0usize;
        let mut next_pop = 0usize;
        for round in 0..1000 {
            let burst = 1 + (round % 3);
            for _ in 0..burst {
                r.try_push(next_push).unwrap();
                next_push += 1;
            }
            for _ in 0..burst {
                assert_eq!(r.try_pop().unwrap(), next_pop);
                next_pop += 1;
            }
        }
        assert!(r.is_empty());
    }

    #[test]
    fn closed_drains_then_reports_closed() {
        let r = Ring::<u32>::new(8);
        r.try_push(1).unwrap();
        r.try_push(2).unwrap();
        r.close();
        assert!(matches!(r.try_push(3), Err(PushError::Closed(3))));
        assert!(!r.push(4));
        assert_eq!(r.pop(), Some(1));
        assert_eq!(r.try_pop(), Ok(2));
        assert_eq!(r.try_pop(), Err(PopError::Closed));
        assert_eq!(r.pop(), None);
    }

    #[test]
    fn concurrent_steal_vs_pop_conserves_items() {
        // One "owner" and two "stealers" race pops on a shared ring while
        // three producers push: every item must surface exactly once.
        // (Scaled down under Miri, whose interpreter runs ~1000x slower.)
        const PER: u64 = if cfg!(miri) { 300 } else { 20_000 };
        const P: usize = 3;
        const C: usize = 3;
        let r = Ring::<u64>::new(64);
        let done = Arc::new(AtomicBool::new(false));
        let producers: Vec<_> = (0..P)
            .map(|pid| {
                let r = r.clone();
                thread::spawn(move || {
                    for i in 0..PER {
                        assert!(r.push(pid as u64 * PER + i));
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..C)
            .map(|_| {
                let r = r.clone();
                let done = done.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match r.try_pop() {
                            Ok(v) => got.push(v),
                            Err(PopError::Closed) => break,
                            Err(PopError::Empty) => {
                                if done.load(Ordering::Acquire) && r.is_empty() {
                                    break;
                                }
                                thread::yield_now();
                            }
                        }
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        done.store(true, Ordering::Release);
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all.len(), P * PER as usize, "lost items");
        all.dedup();
        assert_eq!(all.len(), P * PER as usize, "duplicated items");
    }

    #[test]
    fn drop_while_nonempty_runs_destructors() {
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::SeqCst);
            }
        }
        let r = Ring::<D>::new(8);
        for _ in 0..5 {
            r.try_push(D).unwrap();
        }
        let r2 = r.clone();
        drop(r);
        r2.try_pop().ok(); // consume one normally
        assert_eq!(DROPS.load(Ordering::SeqCst), 1);
        drop(r2); // remaining 4 dropped by the ring itself
        assert_eq!(DROPS.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn drop_after_wraparound_drops_only_live_items() {
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::SeqCst);
            }
        }
        let r = Ring::<D>::new(4);
        // Push/pop past a full lap so stale slots exist, then leave 3 live.
        for _ in 0..6 {
            r.try_push(D).unwrap();
            drop(r.try_pop().unwrap());
        }
        for _ in 0..3 {
            r.try_push(D).unwrap();
        }
        let before = DROPS.load(Ordering::SeqCst);
        drop(r);
        assert_eq!(DROPS.load(Ordering::SeqCst), before + 3);
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        let r = Ring::<u8>::new(5);
        assert_eq!(r.capacity(), 8);
    }

    #[test]
    fn many_producers_many_consumers_under_close() {
        // Producers race the close; consumers must still see exactly the
        // successfully-pushed prefix of each producer's stream.
        const PER: u64 = if cfg!(miri) { 200 } else { 5_000 };
        let r = Ring::<u64>::new(16);
        let pushed = Arc::new(AtomicUsize::new(0));
        let producers: Vec<_> = (0..4)
            .map(|pid| {
                let r = r.clone();
                let pushed = pushed.clone();
                thread::spawn(move || {
                    for i in 0..PER {
                        if r.push(pid * PER + i) {
                            pushed.fetch_add(1, Ordering::SeqCst);
                        } else {
                            break;
                        }
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let r = r.clone();
                thread::spawn(move || {
                    let mut n = 0usize;
                    while r.pop().is_some() {
                        n += 1;
                    }
                    n
                })
            })
            .collect();
        thread::sleep(std::time::Duration::from_millis(5));
        r.close();
        for p in producers {
            p.join().unwrap();
        }
        let consumed: usize = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        assert_eq!(consumed, pushed.load(Ordering::SeqCst));
    }
}
