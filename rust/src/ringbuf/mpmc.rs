//! Bounded blocking MPMC queue (Mutex + Condvar).
//!
//! Used for the low-rate control paths: decisions returning from m samplers
//! to the scheduler (the paper's ZMQ channel) and request admission. The
//! data-plane logits stream uses the lock-free [`super::spsc`] rings instead.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

struct Shared<T> {
    q: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

struct State<T> {
    items: VecDeque<T>,
    senders: usize,
    receivers: usize,
}

/// Sending half (cloneable).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half (cloneable).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// Error: all receivers dropped.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Create a bounded MPMC channel.
pub fn channel<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        q: Mutex::new(State { items: VecDeque::with_capacity(cap), senders: 1, receivers: 1 }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        cap: cap.max(1),
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.q.lock().unwrap().senders += 1;
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.q.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            drop(st);
            self.shared.not_empty.notify_all();
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.shared.q.lock().unwrap().receivers += 1;
        Receiver { shared: self.shared.clone() }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.shared.q.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            drop(st);
            self.shared.not_full.notify_all();
        }
    }
}

impl<T> Sender<T> {
    /// Blocking send; fails only if all receivers are gone.
    pub fn send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.shared.q.lock().unwrap();
        loop {
            if st.receivers == 0 {
                return Err(SendError(item));
            }
            if st.items.len() < self.shared.cap {
                st.items.push_back(item);
                drop(st);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = self.shared.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send; returns the item if full or disconnected.
    pub fn try_send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.shared.q.lock().unwrap();
        if st.receivers == 0 || st.items.len() >= self.shared.cap {
            return Err(SendError(item));
        }
        st.items.push_back(item);
        drop(st);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.shared.q.lock().unwrap().items.len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` when all senders dropped and queue drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.shared.q.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return Some(item);
            }
            if st.senders == 0 {
                return None;
            }
            st = self.shared.not_empty.wait(st).unwrap();
        }
    }

    /// Receive with timeout. `Ok(None)` = disconnected+drained; `Err(())` =
    /// timed out.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<Option<T>, ()> {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.shared.q.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return Ok(Some(item));
            }
            if st.senders == 0 {
                return Ok(None);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(());
            }
            let (guard, res) =
                self.shared.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if res.timed_out() && st.items.is_empty() && st.senders > 0 {
                return Err(());
            }
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.shared.q.lock().unwrap();
        let item = st.items.pop_front();
        if item.is_some() {
            drop(st);
            self.shared.not_full.notify_one();
        }
        item
    }

    pub fn len(&self) -> usize {
        self.shared.q.lock().unwrap().items.len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn send_recv_fifo() {
        let (tx, rx) = channel::<u32>(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.try_recv(), None);
    }

    #[test]
    fn disconnect_on_all_senders_dropped() {
        let (tx, rx) = channel::<u32>(2);
        let tx2 = tx.clone();
        tx.send(5).unwrap();
        drop(tx);
        drop(tx2);
        assert_eq!(rx.recv(), Some(5));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn send_fails_when_receivers_gone() {
        let (tx, rx) = channel::<u32>(2);
        drop(rx);
        assert_eq!(tx.send(1), Err(SendError(1)));
    }

    #[test]
    fn try_send_full() {
        let (tx, _rx) = channel::<u32>(1);
        tx.try_send(1).unwrap();
        assert_eq!(tx.try_send(2), Err(SendError(2)));
    }

    #[test]
    fn recv_timeout_times_out() {
        let (_tx, rx) = channel::<u32>(1);
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)), Err(()));
    }

    #[test]
    fn multi_producer_multi_consumer_conserves_items() {
        let (tx, rx) = channel::<u64>(16);
        const PER: u64 = 10_000;
        const P: usize = 3;
        let producers: Vec<_> = (0..P)
            .map(|pid| {
                let tx = tx.clone();
                thread::spawn(move || {
                    for i in 0..PER {
                        tx.send(pid as u64 * PER + i).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let rx = rx.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = rx.recv() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        drop(rx);
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all.len(), P * PER as usize);
        all.dedup();
        assert_eq!(all.len(), P * PER as usize, "duplicates detected");
    }

    #[test]
    fn blocking_send_unblocks_on_recv() {
        let (tx, rx) = channel::<u32>(1);
        tx.send(0).unwrap();
        let h = thread::spawn(move || tx.send(1).unwrap());
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv(), Some(0));
        assert_eq!(rx.recv(), Some(1));
        h.join().unwrap();
    }
}
