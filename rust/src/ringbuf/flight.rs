//! Flight-recorder ring: a bounded, overwrite-oldest, single-writer record
//! ring over plain `u64` words (DESIGN.md §14).
//!
//! Unlike [`super::spsc`] and [`super::mpmc`] — which are *backpressuring*
//! queues (a full ring rejects the push) — a flight recorder must never
//! stall or grow: when the ring is full the oldest record is silently
//! overwritten, so the buffer always holds the most recent `capacity`
//! records. That is exactly the discipline a tracing subsystem wants on a
//! hot path: writers pay a few relaxed stores and can never block, and a
//! crash leaves the last-N events intact for post-mortem export.
//!
//! Records are fixed-width arrays of `W` words stored as [`AtomicU64`]s, so
//! a reader racing a writer reads *defined* (if stale) values rather than
//! UB; the snapshot protocol below then discards every record that could
//! have been overwritten mid-copy:
//!
//! 1. load `head` (Acquire) → `h1`; the publishable range is
//!    `[h1.saturating_sub(cap), h1)` (records below it are already gone);
//! 2. copy that range oldest-first;
//! 3. load `head` again → `h2`; any copied record with sequence number
//!    `< h2.saturating_sub(cap)` may have been torn by a concurrent
//!    overwrite — drop it from the front.
//!
//! Every record that survives was fully published (the writer's Release
//! store on `head` happens-after its word stores) and never overwritten
//! during the copy, so the snapshot is a consistent, gap-free suffix of
//! the write sequence.

use std::sync::atomic::{AtomicU64, Ordering};

/// Bounded overwrite-oldest ring of `[u64; W]` records. Single writer
/// (the owning thread); any number of concurrent snapshot readers.
pub struct FlightRing<const W: usize> {
    /// Monotonic count of records ever pushed (next sequence number).
    head: AtomicU64,
    /// `capacity * W` words; record `s` lives at `(s % capacity) * W`.
    words: Box<[AtomicU64]>,
    capacity: usize,
}

impl<const W: usize> FlightRing<W> {
    /// A ring holding the most recent `capacity` records (capacity ≥ 1).
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        let words = (0..capacity * W).map(|_| AtomicU64::new(0)).collect();
        FlightRing { head: AtomicU64::new(0), words, capacity }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Records ever pushed (not the retained count; see [`Self::len`]).
    pub fn pushed(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Records currently retained (≤ capacity).
    pub fn len(&self) -> usize {
        (self.pushed() as usize).min(self.capacity)
    }

    pub fn is_empty(&self) -> bool {
        self.pushed() == 0
    }

    /// Append a record, overwriting the oldest if full. Caller contract:
    /// single writer (one owning thread) — concurrent pushes would
    /// interleave slots, not corrupt memory, but lose records.
    #[inline]
    pub fn push(&self, record: &[u64; W]) {
        let h = self.head.load(Ordering::Relaxed);
        let base = (h as usize % self.capacity) * W;
        for (i, &w) in record.iter().enumerate() {
            self.words[base + i].store(w, Ordering::Relaxed);
        }
        // Publish: readers that see head = h+1 see the stores above.
        self.head.store(h + 1, Ordering::Release);
    }

    /// Copy out the retained records, oldest-first, dropping any record a
    /// concurrent writer may have overwritten mid-copy (see module docs).
    pub fn snapshot(&self) -> Vec<[u64; W]> {
        let h1 = self.head.load(Ordering::Acquire);
        let n = (h1 as usize).min(self.capacity);
        let first = h1 - n as u64;
        let mut out = Vec::with_capacity(n);
        for s in first..h1 {
            let base = (s as usize % self.capacity) * W;
            let mut rec = [0u64; W];
            for (i, r) in rec.iter_mut().enumerate() {
                *r = self.words[base + i].load(Ordering::Relaxed);
            }
            out.push(rec);
        }
        let h2 = self.head.load(Ordering::Acquire);
        let oldest_valid = h2.saturating_sub(self.capacity as u64);
        if oldest_valid > first {
            out.drain(..((oldest_valid - first) as usize).min(out.len()));
        }
        out
    }

    /// Reset to empty. Caller contract: no concurrent writer (used by
    /// tests and between experiment cases).
    pub fn clear(&self) {
        self.head.store(0, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retains_last_capacity_records_oldest_first() {
        let ring: FlightRing<2> = FlightRing::new(4);
        for i in 0..10u64 {
            ring.push(&[i, i * 100]);
        }
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 4);
        // overflow drops oldest-first: survivors are 6..10 in order
        assert_eq!(snap.iter().map(|r| r[0]).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        assert!(snap.iter().all(|r| r[1] == r[0] * 100), "records not torn");
    }

    #[test]
    fn partial_fill_returns_everything() {
        let ring: FlightRing<3> = FlightRing::new(8);
        assert!(ring.is_empty());
        ring.push(&[7, 8, 9]);
        ring.push(&[1, 2, 3]);
        let snap = ring.snapshot();
        assert_eq!(snap, vec![[7, 8, 9], [1, 2, 3]]);
        assert_eq!(ring.len(), 2);
        assert_eq!(ring.pushed(), 2);
    }

    #[test]
    fn clear_empties() {
        let ring: FlightRing<1> = FlightRing::new(2);
        ring.push(&[1]);
        ring.clear();
        assert!(ring.snapshot().is_empty());
    }

    #[test]
    fn concurrent_reader_never_sees_torn_records() {
        use std::sync::Arc;
        let ring: Arc<FlightRing<2>> = Arc::new(FlightRing::new(64));
        let writer = {
            let ring = ring.clone();
            std::thread::spawn(move || {
                for i in 0..100_000u64 {
                    ring.push(&[i, !i]);
                }
            })
        };
        let mut checked = 0usize;
        while !writer.is_finished() {
            for rec in ring.snapshot() {
                assert_eq!(rec[1], !rec[0], "torn record survived snapshot");
                checked += 1;
            }
        }
        writer.join().unwrap();
        for rec in ring.snapshot() {
            assert_eq!(rec[1], !rec[0]);
            checked += 1;
        }
        assert!(checked > 0);
    }
}
