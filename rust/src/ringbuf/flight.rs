//! Flight-recorder ring: a bounded, overwrite-oldest, single-writer record
//! ring over plain `u64` words (DESIGN.md §14).
//!
//! Unlike [`super::spsc`] and [`super::mpmc`] — which are *backpressuring*
//! queues (a full ring rejects the push) — a flight recorder must never
//! stall or grow: when the ring is full the oldest record is silently
//! overwritten, so the buffer always holds the most recent `capacity`
//! records. That is exactly the discipline a tracing subsystem wants on a
//! hot path: writers pay a few relaxed stores and can never block, and a
//! crash leaves the last-N events intact for post-mortem export.
//!
//! Records are fixed-width arrays of `W` words stored as [`AtomicU64`]s, so
//! a reader racing a writer reads *defined* (if stale) values rather than
//! UB; the snapshot protocol below then discards every record that could
//! have been overwritten mid-copy. The ring allocates one spare slot
//! (`slots = capacity + 1`): the writer stores record `h`'s words *before*
//! incrementing `head` to `h + 1`, so while `head` reads `h` the slot of
//! record `h - slots` may already be mid-overwrite — the spare slot keeps
//! that victim one step *below* the published `capacity`-record window
//! instead of inside it.
//!
//! 1. load `head` (Acquire) → `h1`; the publishable range is the last
//!    `min(h1, capacity)` records;
//! 2. copy that range oldest-first (relaxed word loads);
//! 3. `fence(Acquire)`, then reload `head` → `h2`; drop any copied record
//!    with sequence number `< h2 - capacity` — with the spare slot, the
//!    writer observed at `head = h2` can only be tearing record
//!    `h2 - capacity - 1`, so everything kept is intact.
//!
//! The fences make the validation sound: the writer's `fence(Release)`
//! before each record's word stores orders the *previous* publish of
//! `head` before them, and the reader's `fence(Acquire)` upgrades its
//! relaxed word loads so the `h2` reload cannot be satisfied before them —
//! if a word load observed an overwrite for record `h`, the reload sees
//! `head ≥ h` and the torn record is filtered. Every record that survives
//! was fully published (the writer's Release store on `head`
//! happens-after its word stores) and never overwritten during the copy,
//! so the snapshot is a consistent, gap-free suffix of the write sequence.
//!
//! Model-checked: `rust/tests/loom_models.rs` replays the writer-overwrite
//! vs. snapshot race on a spare-slot ring — the regression model for the
//! `seq == h2 - capacity` torn-record fix (`make loom`).

use crate::util::sync::atomic::{fence, AtomicU64, Ordering};

/// Bounded overwrite-oldest ring of `[u64; W]` records. Single writer
/// (the owning thread); any number of concurrent snapshot readers.
pub struct FlightRing<const W: usize> {
    /// Monotonic count of records ever pushed (next sequence number).
    head: AtomicU64,
    /// `(capacity + 1) * W` words; record `s` lives at
    /// `(s % (capacity + 1)) * W`. The spare slot is seqlock headroom:
    /// the slot a writer is tearing mid-push is never one the snapshot
    /// publishes (module docs).
    words: Box<[AtomicU64]>,
    capacity: usize,
}

impl<const W: usize> FlightRing<W> {
    /// A ring holding the most recent `capacity` records (capacity ≥ 1).
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        let words = (0..(capacity + 1) * W).map(|_| AtomicU64::new(0)).collect();
        FlightRing { head: AtomicU64::new(0), words, capacity }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Records ever pushed (not the retained count; see [`Self::len`]).
    pub fn pushed(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Records currently retained (≤ capacity).
    pub fn len(&self) -> usize {
        (self.pushed() as usize).min(self.capacity)
    }

    pub fn is_empty(&self) -> bool {
        self.pushed() == 0
    }

    /// Append a record, overwriting the oldest if full. Caller contract:
    /// single writer (one owning thread) — concurrent pushes would
    /// interleave slots, not corrupt memory, but lose records.
    #[inline]
    pub fn push(&self, record: &[u64; W]) {
        let h = self.head.load(Ordering::Relaxed);
        let base = (h as usize % (self.capacity + 1)) * W;
        // Order the previous publish (head = h, Release) before these
        // word stores: a reader that observes one of them, fences
        // (Acquire), and reloads head is then guaranteed to read
        // head ≥ h and filter the record this push is overwriting.
        fence(Ordering::Release);
        for (i, &w) in record.iter().enumerate() {
            // ordering: Relaxed word stores are the seqlock fast path —
            // the Release fence above and the Release head store below
            // bracket them; readers discard any record these stores
            // could have torn (snapshot validation).
            self.words[base + i].store(w, Ordering::Relaxed);
        }
        // Publish: readers that see head = h+1 see the stores above.
        self.head.store(h + 1, Ordering::Release);
    }

    /// Copy out the retained records, oldest-first, dropping any record a
    /// concurrent writer may have overwritten mid-copy (see module docs).
    pub fn snapshot(&self) -> Vec<[u64; W]> {
        let h1 = self.head.load(Ordering::Acquire);
        let n = (h1 as usize).min(self.capacity);
        let first = h1 - n as u64;
        let mut out = Vec::with_capacity(n);
        for s in first..h1 {
            let base = (s as usize % (self.capacity + 1)) * W;
            let mut rec = [0u64; W];
            for (i, r) in rec.iter_mut().enumerate() {
                *r = self.words[base + i].load(Ordering::Relaxed);
            }
            out.push(rec);
        }
        // Upgrade the relaxed word loads above so the head reload below
        // cannot be satisfied before them (seqlock validation).
        fence(Ordering::Acquire);
        let h2 = self.head.load(Ordering::Acquire);
        // A writer observed at head = h2 can be mid-overwrite of record
        // h2 - (capacity + 1) only; with the spare slot, records with
        // seq ≥ h2 - capacity are provably intact.
        let oldest_valid = h2.saturating_sub(self.capacity as u64);
        if oldest_valid > first {
            out.drain(..((oldest_valid - first) as usize).min(out.len()));
        }
        out
    }

    /// Reset to empty. Caller contract: no concurrent writer (used by
    /// tests and between experiment cases).
    pub fn clear(&self) {
        self.head.store(0, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retains_last_capacity_records_oldest_first() {
        let ring: FlightRing<2> = FlightRing::new(4);
        for i in 0..10u64 {
            ring.push(&[i, i * 100]);
        }
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 4);
        // overflow drops oldest-first: survivors are 6..10 in order
        assert_eq!(snap.iter().map(|r| r[0]).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        assert!(snap.iter().all(|r| r[1] == r[0] * 100), "records not torn");
    }

    #[test]
    fn partial_fill_returns_everything() {
        let ring: FlightRing<3> = FlightRing::new(8);
        assert!(ring.is_empty());
        ring.push(&[7, 8, 9]);
        ring.push(&[1, 2, 3]);
        let snap = ring.snapshot();
        assert_eq!(snap, vec![[7, 8, 9], [1, 2, 3]]);
        assert_eq!(ring.len(), 2);
        assert_eq!(ring.pushed(), 2);
    }

    #[test]
    fn clear_empties() {
        let ring: FlightRing<1> = FlightRing::new(2);
        ring.push(&[1]);
        ring.clear();
        assert!(ring.snapshot().is_empty());
    }

    #[test]
    fn concurrent_reader_never_sees_torn_records() {
        use std::sync::Arc;
        let ring: Arc<FlightRing<2>> = Arc::new(FlightRing::new(64));
        const N: u64 = if cfg!(miri) { 2_000 } else { 100_000 };
        let writer = {
            let ring = ring.clone();
            std::thread::spawn(move || {
                for i in 0..N {
                    ring.push(&[i, !i]);
                }
            })
        };
        let mut checked = 0usize;
        while !writer.is_finished() {
            for rec in ring.snapshot() {
                assert_eq!(rec[1], !rec[0], "torn record survived snapshot");
                checked += 1;
            }
        }
        writer.join().unwrap();
        for rec in ring.snapshot() {
            assert_eq!(rec[1], !rec[0]);
            checked += 1;
        }
        assert!(checked > 0);
    }

    /// A capacity-3 ring overwrites on almost every push, so every
    /// snapshot races an in-flight overwrite — the seqlock filter must
    /// still yield untorn, consecutive records. This is the regime where
    /// keeping seq == h2 - capacity from a cap-slot ring was torn.
    #[test]
    fn tiny_ring_snapshots_stay_untorn_and_contiguous() {
        use std::sync::Arc;
        let ring: Arc<FlightRing<2>> = Arc::new(FlightRing::new(3));
        const N: u64 = if cfg!(miri) { 3_000 } else { 200_000 };
        let writer = {
            let ring = ring.clone();
            std::thread::spawn(move || {
                for i in 0..N {
                    ring.push(&[i, !i]);
                }
            })
        };
        let mut checked = 0usize;
        while !writer.is_finished() {
            let snap = ring.snapshot();
            for rec in &snap {
                assert_eq!(rec[1], !rec[0], "torn record survived snapshot");
            }
            for w in snap.windows(2) {
                assert_eq!(w[1][0], w[0][0] + 1, "snapshot not a contiguous suffix");
            }
            checked += snap.len();
        }
        writer.join().unwrap();
        assert_eq!(ring.snapshot().len(), 3, "full ring retains `capacity` records");
        assert!(checked > 0);
    }
}
