//! Bounded lock-free SPSC ring buffer with cache-padded head/tail indices —
//! the in-process analog of the paper's shared-memory rings (§4.2): one
//! producer (a final-stage GPU worker) and one consumer (a CPU sampler)
//! advance independently, giving the overlap SIMPLE relies on.
//!
//! Model-checked: `rust/tests/loom_models.rs` drives a concurrent
//! transfer with close on this exact type (`make loom`).

use crate::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::util::sync::cell::UnsafeCell;
use crate::util::sync::{arc_strong_count, hint, thread, Arc};
use std::mem::MaybeUninit;

/// Pad to a cache line to avoid false sharing between producer and consumer
/// indices (crossbeam's CachePadded, hand-rolled).
#[repr(align(64))]
struct CachePadded<T>(T);

struct Inner<T> {
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    cap: usize,
    /// Next slot the producer will write (monotonic, mod cap on access).
    head: CachePadded<AtomicUsize>,
    /// Next slot the consumer will read.
    tail: CachePadded<AtomicUsize>,
    closed: AtomicBool,
}

// SAFETY: exactly one producer writes cells in [tail, head) order and
// exactly one consumer reads them; the Release/Acquire handoff on head
// and tail serializes every cell access, so the ring is Sync whenever
// the payload is Send.
unsafe impl<T: Send> Send for Inner<T> {}
// SAFETY: as above — single producer, single consumer, index handoff.
unsafe impl<T: Send> Sync for Inner<T> {}

/// Producer handle.
pub struct Producer<T> {
    inner: Arc<Inner<T>>,
}

/// Consumer handle.
pub struct Consumer<T> {
    inner: Arc<Inner<T>>,
}

/// Error returned by `try_push` when the ring is full (item handed back).
#[derive(Debug)]
pub struct Full<T>(pub T);

/// Error returned by pop on an empty+closed ring.
#[derive(Debug, PartialEq, Eq)]
pub enum PopError {
    Empty,
    Closed,
}

/// Create a bounded SPSC ring of capacity `cap` (rounded up to a power of
/// two for cheap masking).
pub fn ring<T>(cap: usize) -> (Producer<T>, Consumer<T>) {
    let cap = cap.max(2).next_power_of_two();
    let buf: Box<[UnsafeCell<MaybeUninit<T>>]> =
        (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
    let inner = Arc::new(Inner {
        buf,
        cap,
        head: CachePadded(AtomicUsize::new(0)),
        tail: CachePadded(AtomicUsize::new(0)),
        closed: AtomicBool::new(false),
    });
    (Producer { inner: inner.clone() }, Consumer { inner })
}

impl<T> Producer<T> {
    /// Non-blocking push; returns the item if the ring is full.
    pub fn try_push(&self, item: T) -> Result<(), Full<T>> {
        let inner = &self.inner;
        let head = inner.head.0.load(Ordering::Relaxed);
        let tail = inner.tail.0.load(Ordering::Acquire);
        if head - tail == inner.cap {
            return Err(Full(item));
        }
        let slot = &inner.buf[head & (inner.cap - 1)];
        // SAFETY: single producer — only this thread writes cells — and
        // the Acquire tail load proved the consumer has vacated slot
        // `head - cap`, so the cell is ours until the head store below.
        slot.with_mut(|p| unsafe { (*p).write(item) });
        inner.head.0.store(head + 1, Ordering::Release);
        Ok(())
    }

    /// Spin-then-yield blocking push. Returns `false` if the consumer is
    /// gone (item dropped).
    pub fn push(&self, mut item: T) -> bool {
        let mut spins = 0u32;
        loop {
            match self.try_push(item) {
                Ok(()) => return true,
                Err(Full(back)) => {
                    if arc_strong_count(&self.inner) == 1 {
                        return false; // consumer dropped
                    }
                    item = back;
                    spins += 1;
                    if spins < 64 {
                        hint::spin_loop();
                    } else {
                        thread::yield_now();
                    }
                }
            }
        }
    }

    /// Mark the stream finished; consumers see `PopError::Closed` once
    /// drained.
    pub fn close(&self) {
        self.inner.closed.store(true, Ordering::Release);
    }

    /// Number of items currently queued.
    pub fn len(&self) -> usize {
        self.inner.head.0.load(Ordering::Relaxed) - self.inner.tail.0.load(Ordering::Relaxed)
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn capacity(&self) -> usize {
        self.inner.cap
    }
}

impl<T> Drop for Producer<T> {
    fn drop(&mut self) {
        self.close();
    }
}

impl<T> Consumer<T> {
    /// Non-blocking pop.
    pub fn try_pop(&self) -> Result<T, PopError> {
        let inner = &self.inner;
        let tail = inner.tail.0.load(Ordering::Relaxed);
        let head = inner.head.0.load(Ordering::Acquire);
        if tail == head {
            return if inner.closed.load(Ordering::Acquire) {
                // Re-check: producer may have pushed between head load and
                // closed load.
                if inner.head.0.load(Ordering::Acquire) != tail {
                    self.try_pop()
                } else {
                    Err(PopError::Closed)
                }
            } else {
                Err(PopError::Empty)
            };
        }
        let slot = &inner.buf[tail & (inner.cap - 1)];
        // SAFETY: single consumer — only this thread reads cells — and
        // the Acquire head load saw the producer publish slot `tail`, so
        // the value is fully written and ours until the tail store below.
        let item = slot.with_mut(|p| unsafe { (*p).assume_init_read() });
        inner.tail.0.store(tail + 1, Ordering::Release);
        Ok(item)
    }

    /// Spin-then-yield blocking pop; `None` when closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut spins = 0u32;
        loop {
            match self.try_pop() {
                Ok(item) => return Some(item),
                Err(PopError::Closed) => return None,
                Err(PopError::Empty) => {
                    spins += 1;
                    if spins < 64 {
                        hint::spin_loop();
                    } else {
                        thread::yield_now();
                    }
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        self.inner.head.0.load(Ordering::Relaxed) - self.inner.tail.0.load(Ordering::Relaxed)
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Drop for Consumer<T> {
    fn drop(&mut self) {
        // Drain remaining initialized items so T's Drop runs.
        while let Ok(item) = self.try_pop() {
            drop(item);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_single_thread() {
        let (p, c) = ring::<u32>(8);
        for i in 0..8 {
            p.try_push(i).unwrap();
        }
        assert!(p.try_push(99).is_err(), "ring should be full");
        for i in 0..8 {
            assert_eq!(c.try_pop().unwrap(), i);
        }
        assert_eq!(c.try_pop(), Err(PopError::Empty));
    }

    #[test]
    fn close_signals_consumer() {
        let (p, c) = ring::<u32>(4);
        p.try_push(1).unwrap();
        p.close();
        assert_eq!(c.try_pop().unwrap(), 1); // drains before Closed
        assert_eq!(c.try_pop(), Err(PopError::Closed));
        assert_eq!(c.pop(), None);
    }

    #[test]
    fn producer_drop_closes() {
        let (p, c) = ring::<u32>(4);
        p.try_push(7).unwrap();
        drop(p);
        assert_eq!(c.pop(), Some(7));
        assert_eq!(c.pop(), None);
    }

    #[test]
    fn wraps_around_many_times() {
        let (p, c) = ring::<usize>(4);
        for i in 0..1000 {
            p.try_push(i).unwrap();
            assert_eq!(c.try_pop().unwrap(), i);
        }
    }

    #[test]
    fn concurrent_producer_consumer_no_loss_no_dup() {
        let (p, c) = ring::<u64>(64);
        const N: u64 = if cfg!(miri) { 2_000 } else { 200_000 };
        let producer = std::thread::spawn(move || {
            for i in 0..N {
                assert!(p.push(i));
            }
        });
        let mut expected = 0u64;
        let mut sum = 0u64;
        while let Some(v) = c.pop() {
            assert_eq!(v, expected, "out of order");
            expected += 1;
            sum += v;
        }
        producer.join().unwrap();
        assert_eq!(expected, N);
        assert_eq!(sum, N * (N - 1) / 2);
    }

    #[test]
    fn drops_run_for_undrained_items() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        #[derive(Debug)]
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::SeqCst);
            }
        }
        let (p, c) = ring::<D>(8);
        for _ in 0..5 {
            p.try_push(D).unwrap();
        }
        drop(c);
        drop(p);
        assert_eq!(DROPS.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        let (p, _c) = ring::<u8>(5);
        assert_eq!(p.capacity(), 8);
    }
}
