//! Shared-memory ring buffers — the transport of SIMPLE's data flow (§4.2).
//!
//! The paper carries three streams over shared-memory rings: scheduling
//! outputs, TP-sharded logits blocks, and auxiliary sampler inputs
//! (pre-generated randoms); decisions return over a lightweight channel.
//! Producers and consumers advance independently so the decision plane
//! overlaps with GPU compute.
//!
//! This module provides the in-process analog:
//! - [`spsc::Ring`] — bounded lock-free single-producer/single-consumer ring
//!   with cache-padded indices (one ring per worker↔sampler edge).
//! - [`mpmc::Ring`] — bounded *lock-free* MPMC ring (Vyukov sequence-slot
//!   queue): the sharded per-worker task queues of the shared sampler pool,
//!   pushed by any number of engine replicas and popped by the owning
//!   worker or a work-stealing sibling.
//! - [`LogitsPool`] — a pool of reusable, reference-counted logits slabs: the
//!   "shared memory region" GPU workers write vocabulary-major slices into
//!   and samplers read zero-copy.
//! - [`flight::FlightRing`] — bounded overwrite-oldest record ring (never
//!   blocks, never grows): the per-thread event buffer of the flight-recorder
//!   tracing subsystem ([`crate::trace`], DESIGN.md §14).

pub mod flight;
pub mod mpmc;
pub mod spsc;

use std::sync::{Arc, Mutex};

/// A reusable slab of f32s representing one iteration's vocabulary-major
/// logits block (`[V_shard x B]`) in the shared region.
///
/// Slabs are handed out by [`LogitsPool`]; dropping the last reader returns
/// the slab to the pool, modelling ring-slot reuse without allocation on the
/// hot path.
pub struct LogitsSlab {
    data: Box<[f32]>,
    pool: Option<Arc<PoolInner>>,
}

impl LogitsSlab {
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

impl Drop for LogitsSlab {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            let data = std::mem::take(&mut self.data);
            let mut free = pool.free.lock().unwrap();
            if free.len() < pool.max_retained {
                free.push(data);
            }
        }
    }
}

struct PoolInner {
    // cold: the free-slab stack is touched only on slab recycle/refill —
    // samplers read acquired slabs zero-copy, never through this lock.
    free: Mutex<Vec<Box<[f32]>>>,
    max_retained: usize,
    slab_len: usize,
}

/// Allocation-free (steady-state) pool of logits slabs.
#[derive(Clone)]
pub struct LogitsPool {
    inner: Arc<PoolInner>,
}

impl LogitsPool {
    /// Pool of slabs of `slab_len` f32s, retaining at most `max_retained`
    /// free slabs (ring depth).
    pub fn new(slab_len: usize, max_retained: usize) -> Self {
        LogitsPool {
            inner: Arc::new(PoolInner {
                // cold: pool refill path (see the field's note above)
                free: Mutex::new(Vec::new()),
                max_retained,
                slab_len,
            }),
        }
    }

    /// Grab a slab (recycled if available). Contents are NOT zeroed — the
    /// producer overwrites every cell, like a ring slot.
    pub fn acquire(&self) -> LogitsSlab {
        let recycled = self.inner.free.lock().unwrap().pop();
        let data = recycled
            .unwrap_or_else(|| vec![0.0f32; self.inner.slab_len].into_boxed_slice());
        LogitsSlab { data, pool: Some(self.inner.clone()) }
    }

    pub fn slab_len(&self) -> usize {
        self.inner.slab_len
    }

    /// Number of currently retained free slabs (observability).
    pub fn free_count(&self) -> usize {
        self.inner.free.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_recycles_slabs() {
        let pool = LogitsPool::new(16, 4);
        assert_eq!(pool.free_count(), 0);
        let s = pool.acquire();
        assert_eq!(s.len(), 16);
        drop(s);
        assert_eq!(pool.free_count(), 1);
        let _s2 = pool.acquire();
        assert_eq!(pool.free_count(), 0); // reused, not newly stashed
    }

    #[test]
    fn pool_caps_retained() {
        let pool = LogitsPool::new(4, 2);
        let slabs: Vec<_> = (0..5).map(|_| pool.acquire()).collect();
        drop(slabs);
        assert_eq!(pool.free_count(), 2);
    }

    #[test]
    fn slab_write_read() {
        let pool = LogitsPool::new(8, 1);
        let mut s = pool.acquire();
        for (i, v) in s.as_mut_slice().iter_mut().enumerate() {
            *v = i as f32;
        }
        assert_eq!(s.as_slice()[7], 7.0);
    }
}
