//! Small self-contained utilities (the offline image has no clap/serde/log,
//! so these substrates are hand-built and tested here).

pub mod argparse;
pub mod json;
pub mod logging;
pub mod sync;

use std::time::{Duration, Instant};

/// Measure wall time of a closure, returning `(result, elapsed)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Format a duration as a human-readable string with µs/ms/s units.
pub fn fmt_duration(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1_000.0 {
        format!("{us:.1}µs")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

/// Integer ceiling division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Partition `n` items into `m` contiguous, near-equal index ranges
/// (the sequence-parallel batch partition `B_1..B_m` of paper §5.1).
/// Earlier ranges get the remainder; empty ranges are omitted.
pub fn partition_ranges(n: usize, m: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 || m == 0 {
        return Vec::new();
    }
    let m = m.min(n);
    let base = n / m;
    let rem = n % m;
    let mut out = Vec::with_capacity(m);
    let mut start = 0;
    for j in 0..m {
        let len = base + usize::from(j < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Ensure a directory exists (mkdir -p).
pub fn ensure_dir(path: &std::path::Path) -> std::io::Result<()> {
    std::fs::create_dir_all(path)
}

/// FNV-1a digest over `(sequence id, committed tokens)` streams, id-ordered
/// — THE deterministic token-stream fingerprint. `serve_e2e` prints it per
/// engine variant and the `overlap` harness cross-checks it across
/// executor configurations; both must hash identically, which is why this
/// lives here and not in either caller.
pub fn stream_digest(mut streams: Vec<(u64, Vec<u32>)>) -> u64 {
    streams.sort();
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for (id, tokens) in &streams {
        eat(*id);
        eat(tokens.len() as u64);
        for &t in tokens {
            eat(t as u64);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_digest_is_order_invariant_and_content_sensitive() {
        let a = stream_digest(vec![(0, vec![1, 2]), (1, vec![3])]);
        let b = stream_digest(vec![(1, vec![3]), (0, vec![1, 2])]);
        assert_eq!(a, b, "id-ordered: input order must not matter");
        let c = stream_digest(vec![(0, vec![1, 2]), (1, vec![4])]);
        assert_ne!(a, c, "different tokens must move the digest");
        // length-prefixing separates (tokens, id) boundaries
        let d = stream_digest(vec![(0, vec![1, 2, 3])]);
        let e = stream_digest(vec![(0, vec![1, 2]), (3, vec![])]);
        assert_ne!(d, e);
    }

    #[test]
    fn ceil_div_rounds_up() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn partition_covers_all_indices_without_overlap() {
        for n in [0usize, 1, 7, 32, 256, 1000] {
            for m in [1usize, 2, 3, 16, 64] {
                let ranges = partition_ranges(n, m);
                let mut covered = vec![false; n];
                for r in &ranges {
                    for i in r.clone() {
                        assert!(!covered[i], "index {i} covered twice (n={n}, m={m})");
                        covered[i] = true;
                    }
                }
                assert!(covered.iter().all(|&c| c), "not all covered (n={n}, m={m})");
            }
        }
    }

    #[test]
    fn partition_is_balanced() {
        let ranges = partition_ranges(10, 3);
        let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
        assert_eq!(lens, vec![4, 3, 3]);
        // sizes differ by at most one
        for n in [5usize, 17, 100] {
            for m in [2usize, 4, 7] {
                let lens: Vec<usize> =
                    partition_ranges(n, m).iter().map(|r| r.len()).collect();
                let mx = *lens.iter().max().unwrap();
                let mn = *lens.iter().min().unwrap();
                assert!(mx - mn <= 1);
            }
        }
    }

    #[test]
    fn partition_degenerate_cases() {
        assert!(partition_ranges(0, 4).is_empty());
        assert!(partition_ranges(4, 0).is_empty());
        // more workers than items: one range per item
        let ranges = partition_ranges(3, 8);
        assert_eq!(ranges.len(), 3);
        assert!(ranges.iter().all(|r| r.len() == 1));
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(Duration::from_micros(12)), "12.0µs");
        assert_eq!(fmt_duration(Duration::from_millis(3)), "3.00ms");
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000s");
    }
}
