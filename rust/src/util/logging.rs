//! Tiny leveled logger (the `log`/`env_logger` pair is unavailable offline).
//!
//! Controlled by `SIMPLE_LOG` (error|warn|info|debug|trace, default info).
//! Thread-safe; timestamps are seconds since the shared trace epoch
//! ([`crate::trace::epoch`]) — the same clock the flight recorder and the
//! `Recorder` use, so a log line's `t` can be lined up against spans in a
//! capture. WARN and ERROR records are additionally emitted as trace
//! instant events when tracing is on (DESIGN.md §14).

// host atomics: LEVEL is a const-initialized global cache, outside the
// loom-modeled surface (see crate::util::sync docs).
use crate::util::sync::host::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
    fn from_env(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != u8::MAX {
        return v;
    }
    let lv = std::env::var("SIMPLE_LOG")
        .map(|s| Level::from_env(&s))
        .unwrap_or(Level::Info) as u8;
    // ordering: Relaxed — an idempotent cache fill; racing initializers
    // compute and store the same value.
    LEVEL.store(lv, Ordering::Relaxed);
    lv
}

/// Override the log level programmatically (tests, CLI flags).
pub fn set_level(lv: Level) {
    // ordering: Relaxed — the level is an advisory print gate; a stale
    // read misprints at most one line's verbosity.
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

pub fn enabled(lv: Level) -> bool {
    (lv as u8) <= level()
}

pub fn log(lv: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if lv <= Level::Warn {
        // WARN+ records count and trace regardless of the print gate — a
        // suppressed warning should still be visible in a capture.
        crate::trace::metrics::inc(&crate::trace::metrics::counters().log_warnings);
        if crate::trace::on() {
            let id = crate::trace::intern(&format!("{} [{module}] {msg}", lv.as_str().trim()));
            crate::trace::instant(crate::trace::Kind::Log, id, lv as u64);
        }
    }
    if !enabled(lv) {
        return;
    }
    // Seconds since the shared trace epoch — comparable to span timestamps.
    let t = crate::trace::epoch().elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {} {module}] {msg}", lv.as_str());
}

#[macro_export]
macro_rules! log_error { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_warn { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_info { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_debug { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_trace { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_gates_output() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Trace));
        set_level(Level::Info); // restore default-ish for other tests
    }

    #[test]
    fn from_env_strings() {
        assert_eq!(Level::from_env("ERROR"), Level::Error);
        assert_eq!(Level::from_env("debug"), Level::Debug);
        assert_eq!(Level::from_env("bogus"), Level::Info);
    }
}
