//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Used for: artifact manifests written by `python/compile/aot.py`, config
//! files, and `results/*.json` emitted by the figure harnesses. Supports the
//! full JSON grammar; numbers are kept as f64 (adequate for our manifests —
//! shapes and counts are far below 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// JSON parse error with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    // ---- constructors ----
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num_arr<'a, I: IntoIterator<Item = &'a f64>>(items: I) -> Json {
        Json::Arr(items.into_iter().map(|&v| Json::Num(v)).collect())
    }

    // ---- accessors ----
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n.fract() == 0.0 && n >= 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---- parsing ----
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- serialization ----
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }
    /// Pretty-print with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    // JSON has no Inf/NaN; emit null (matches python json default-ish).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }
    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character {:?}", c as char))),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: best-effort (manifests are ASCII).
                            if let Some(ch) = char::from_u32(code) {
                                s.push(ch);
                            } else {
                                s.push('\u{fffd}');
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = (start + len).min(self.b.len());
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

/// Read and parse a JSON file.
pub fn read_json_file(path: &std::path::Path) -> crate::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

/// Write a JSON file (pretty), creating parent dirs.
pub fn write_json_file(path: &std::path::Path, value: &Json) -> crate::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, value.to_string_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-3.5", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "roundtrip {text}");
        }
    }

    #[test]
    fn parses_nested_structure() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x", "c": null}], "d": true}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(v.get("d").as_bool(), Some(true));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = Json::Str("line1\nline2\t\"q\"\\end\u{1}".to_string());
        let parsed = Json::parse(&original.to_string()).unwrap();
        assert_eq!(parsed, original);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
        let v2 = Json::parse("\"\\u0041\\u00e9\"").unwrap();
        assert_eq!(v2.as_str(), Some("Aé"));
    }

    #[test]
    fn numbers_parse_exactly() {
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("-1.5").unwrap().as_f64(), Some(-1.5));
        assert_eq!(Json::parse("2.5e2").unwrap().as_f64(), Some(250.0));
        assert_eq!(Json::parse("-1.5").unwrap().as_usize(), None);
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"abc", "{\"a\" 1}", "tru", "1 2", "", "[1,]"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = Json::obj(vec![
            ("name", Json::Str("fig3".into())),
            ("series", Json::num_arr(&[1.0, 2.5, 3.0])),
            ("nested", Json::obj(vec![("k", Json::Bool(false))])),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("simple_serve_json_test");
        let path = dir.join("x.json");
        let v = Json::obj(vec![("a", Json::Num(1.0))]);
        write_json_file(&path, &v).unwrap();
        assert_eq!(read_json_file(&path).unwrap(), v);
        std::fs::remove_dir_all(&dir).ok();
    }
}
