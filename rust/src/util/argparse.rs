//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands. Typed getters parse on access and report errors with the
//! offending flag name.

use std::collections::BTreeMap;
use std::fmt::Display;
use std::str::FromStr;

/// Parsed command line: subcommand (optional), key/value options, flags,
/// and positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Program name (argv[0]).
    pub program: String,
    /// First non-flag token, if the caller requested subcommand parsing.
    pub subcommand: Option<String>,
    options: BTreeMap<String, Vec<String>>,
    positionals: Vec<String>,
}

/// Declarative spec for one option, used for `--help` output and to know
/// which options consume a value.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

impl OptSpec {
    pub const fn flag(name: &'static str, help: &'static str) -> Self {
        Self { name, takes_value: false, help }
    }
    pub const fn value(name: &'static str, help: &'static str) -> Self {
        Self { name, takes_value: true, help }
    }
}

/// Argument parsing error.
#[derive(Debug, thiserror::Error)]
pub enum ArgError {
    #[error("unknown option --{0}")]
    Unknown(String),
    #[error("option --{0} requires a value")]
    MissingValue(String),
    #[error("invalid value {value:?} for --{name}: {msg}")]
    Invalid { name: String, value: String, msg: String },
    #[error("missing required option --{0}")]
    MissingRequired(String),
}

impl Args {
    /// Parse `std::env::args()` against a spec. If `with_subcommand`, the
    /// first bare token becomes [`Args::subcommand`].
    pub fn parse_env(specs: &[OptSpec], with_subcommand: bool) -> Result<Self, ArgError> {
        let argv: Vec<String> = std::env::args().collect();
        Self::parse(&argv, specs, with_subcommand)
    }

    /// Parse an explicit argv (index 0 is the program name).
    pub fn parse(
        argv: &[String],
        specs: &[OptSpec],
        with_subcommand: bool,
    ) -> Result<Self, ArgError> {
        let mut args = Args {
            program: argv.first().cloned().unwrap_or_default(),
            ..Default::default()
        };
        let spec_for = |name: &str| specs.iter().find(|s| s.name == name);
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = spec_for(&name).ok_or_else(|| ArgError::Unknown(name.clone()))?;
                let value = if spec.takes_value {
                    match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| ArgError::MissingValue(name.clone()))?
                        }
                    }
                } else {
                    inline_val.unwrap_or_else(|| "true".to_string())
                };
                args.options.entry(name).or_default().push(value);
            } else if with_subcommand && args.subcommand.is_none() && args.positionals.is_empty()
            {
                args.subcommand = Some(tok.clone());
            } else {
                args.positionals.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// True if `--name` was given (as a flag or with any value).
    pub fn flag(&self, name: &str) -> bool {
        self.options.contains_key(name)
    }

    /// Last occurrence of `--name`'s raw value.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All occurrences of `--name`.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.options
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    /// Typed getter with default.
    pub fn get_or<T: FromStr>(&self, name: &str, default: T) -> Result<T, ArgError>
    where
        T::Err: Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(raw) => raw.parse().map_err(|e: T::Err| ArgError::Invalid {
                name: name.to_string(),
                value: raw.to_string(),
                msg: e.to_string(),
            }),
        }
    }

    /// Typed getter, required.
    pub fn require<T: FromStr>(&self, name: &str) -> Result<T, ArgError>
    where
        T::Err: Display,
    {
        let raw = self
            .get(name)
            .ok_or_else(|| ArgError::MissingRequired(name.to_string()))?;
        raw.parse().map_err(|e: T::Err| ArgError::Invalid {
            name: name.to_string(),
            value: raw.to_string(),
            msg: e.to_string(),
        })
    }

    /// Positional arguments (excluding the subcommand).
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

/// Render a `--help` block from specs.
pub fn render_help(program: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut out = format!("{program} — {about}\n\nOptions:\n");
    for s in specs {
        let arg = if s.takes_value {
            format!("--{} <v>", s.name)
        } else {
            format!("--{}", s.name)
        };
        out.push_str(&format!("  {arg:<24} {}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    const SPECS: &[OptSpec] = &[
        OptSpec::value("batch", "batch size"),
        OptSpec::value("model", "model name"),
        OptSpec::flag("quick", "quick mode"),
    ];

    #[test]
    fn parses_key_value_and_flags() {
        let a = Args::parse(&sv(&["p", "--batch", "32", "--quick"]), SPECS, false).unwrap();
        assert_eq!(a.get_or("batch", 0usize).unwrap(), 32);
        assert!(a.flag("quick"));
        assert!(!a.flag("model"));
    }

    #[test]
    fn parses_equals_form() {
        let a = Args::parse(&sv(&["p", "--batch=64"]), SPECS, false).unwrap();
        assert_eq!(a.get_or("batch", 0usize).unwrap(), 64);
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = Args::parse(&sv(&["p", "serve", "file.json", "--quick"]), SPECS, true).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.positionals(), &["file.json".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        let err = Args::parse(&sv(&["p", "--nope"]), SPECS, false).unwrap_err();
        assert!(matches!(err, ArgError::Unknown(_)));
    }

    #[test]
    fn missing_value_errors() {
        let err = Args::parse(&sv(&["p", "--batch"]), SPECS, false).unwrap_err();
        assert!(matches!(err, ArgError::MissingValue(_)));
    }

    #[test]
    fn invalid_typed_value_errors() {
        let a = Args::parse(&sv(&["p", "--batch", "abc"]), SPECS, false).unwrap();
        assert!(a.get_or("batch", 0usize).is_err());
    }

    #[test]
    fn last_occurrence_wins_and_all_are_kept() {
        let a =
            Args::parse(&sv(&["p", "--model", "a", "--model", "b"]), SPECS, false).unwrap();
        assert_eq!(a.get("model"), Some("b"));
        assert_eq!(a.get_all("model"), vec!["a", "b"]);
    }

    #[test]
    fn required_missing_errors() {
        let a = Args::parse(&sv(&["p"]), SPECS, false).unwrap();
        assert!(matches!(
            a.require::<usize>("batch").unwrap_err(),
            ArgError::MissingRequired(_)
        ));
    }
}
