//! Synchronization shim: `std::sync` in production, `loom` under
//! `--cfg loom` (DESIGN.md §15).
//!
//! Every lock-free module (`ringbuf/{mpmc,spsc,flight}`,
//! `decision/{slots,seqrec,service}`, the `trace` fast path,
//! `util/logging`, `cluster/replica` heartbeats) imports its atomics,
//! cells, and internal `Arc`s from here instead of `std::sync`, so
//! `make loom` model-checks the *real* production types — not parallel
//! reimplementations. Without `--cfg loom` everything re-exports `std`
//! and compiles to exactly the code we shipped before the shim existed.
//!
//! What deliberately stays host-side (`std`), even under loom:
//!
//! - **Const-initialized process globals** (`trace::ENABLED`, the
//!   metrics counters/histograms, `logging::LEVEL`): loom atomics are
//!   not const-constructible and may only be created inside
//!   `loom::model`. Those statics import from [`host`] and are outside
//!   the modeled surface — they are monotonic or advisory and never
//!   carry a happens-before edge the decision plane relies on.
//! - **OS thread spawning** (`std::thread::Builder` in
//!   `decision/service.rs` and `cluster/replica.rs`): loom schedules
//!   its own coroutine threads; real spawns are exercised by the TSan
//!   lane (`make tsan`) instead.
//! - **Payload reference counts** (`Arc<IterationTask>`, `SeqHandle`,
//!   the trace registry): plain data handed across the boundary to
//!   non-modeled layers (engine, scheduler, router). Inside a loom
//!   model a `std::sync::Arc` clone/drop is an ordinary correct
//!   operation; the protocol state the models verify lives entirely in
//!   shimmed atomics and cells.

#[cfg(not(loom))]
pub mod atomic {
    pub use std::sync::atomic::{
        fence, AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
    };
}

#[cfg(loom)]
pub mod atomic {
    pub use loom::sync::atomic::{
        fence, AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
    };
}

#[cfg(not(loom))]
pub use std::sync::{Arc, Mutex};

#[cfg(loom)]
pub use loom::sync::{Arc, Mutex};

/// Always-`std` atomics for const-initialized process globals (metrics
/// counters, the tracing enable flag, the log-level cache). Loom
/// atomics cannot live in a `static`, and these globals are outside
/// the modeled surface by design — see the module docs.
pub mod host {
    pub use std::sync::atomic::{
        AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
    };
}

/// `UnsafeCell` with loom's closure-based access API on both sides.
///
/// Loom's cell hands out raw pointers through `with`/`with_mut` so it
/// can dynamically verify that no two threads touch the contents
/// concurrently (unless both use `with`). The production arm is a
/// zero-cost wrapper over `std::cell::UnsafeCell` with the same shape,
/// so call sites are identical in both builds. Dereferencing the
/// pointer inside the closure still requires `unsafe` — the caller
/// owns the exclusivity argument and states it in a `// SAFETY:`
/// comment, which the concurrency lint enforces.
pub mod cell {
    #[cfg(loom)]
    pub use loom::cell::UnsafeCell;

    #[cfg(not(loom))]
    #[derive(Debug)]
    pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

    #[cfg(not(loom))]
    impl<T> UnsafeCell<T> {
        pub const fn new(value: T) -> UnsafeCell<T> {
            UnsafeCell(std::cell::UnsafeCell::new(value))
        }

        #[inline(always)]
        pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
            f(self.0.get())
        }

        #[inline(always)]
        pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
            f(self.0.get())
        }
    }
}

/// Spin-loop hint; loom turns it into a scheduling point.
pub mod hint {
    #[cfg(not(loom))]
    pub use std::hint::spin_loop;

    #[cfg(loom)]
    pub fn spin_loop() {
        loom::thread::yield_now();
    }
}

/// Cooperative yield for bounded retry loops.
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::yield_now;

    #[cfg(loom)]
    pub use loom::thread::yield_now;
}

/// `fetch_max` on an [`atomic::AtomicUsize`]. Loom's atomics do not
/// provide the native RMW, so the loom arm emulates it with a CAS loop
/// (same linearizable effect, and loom explores the retries); the
/// production arm is the single hardware RMW.
#[inline]
pub fn fetch_max_usize(
    a: &atomic::AtomicUsize,
    val: usize,
    order: atomic::Ordering,
) -> usize {
    #[cfg(not(loom))]
    {
        a.fetch_max(val, order)
    }
    #[cfg(loom)]
    {
        // ordering: the Relaxed probe only seeds the CAS; the CAS
        // itself carries `order` on success, matching fetch_max.
        let mut cur = a.load(atomic::Ordering::Relaxed);
        loop {
            if cur >= val {
                return cur;
            }
            // ordering: failure is Relaxed — a lost race just reloads
            // the observed value and retries; `order` on success is the
            // caller's publication edge, as with the native RMW.
            match a.compare_exchange(cur, val, order, atomic::Ordering::Relaxed) {
                Ok(prev) => return prev,
                Err(now) => cur = now,
            }
        }
    }
}

/// `Arc::strong_count`, pessimistic under loom.
///
/// The SPSC producer uses the count only as a liveness hint ("has the
/// consumer dropped?"). Loom's `Arc` does not expose `strong_count`,
/// so the loom arm reports the consumer alive forever — models drive
/// the non-blocking `try_push` path, where the hint is never consulted.
#[inline]
pub fn arc_strong_count<T>(a: &Arc<T>) -> usize {
    #[cfg(not(loom))]
    {
        Arc::strong_count(a)
    }
    #[cfg(loom)]
    {
        let _ = a;
        2
    }
}
