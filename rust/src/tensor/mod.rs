//! Minimal 2-D tensor types for the decision plane.
//!
//! Layouts matter here more than generality: the paper's workflow transposes
//! logits to **vocabulary-major** `[V/t × B]` before writing them to shared
//! memory (step ②–③) so CPU samplers scan columns contiguously, and samplers
//! reconstruct full-vocabulary views per sequence by concatenating the
//! rank-local slices **without copies** (step ④). [`ShardedLogits`] is that
//! zero-copy view.
//!
//! Shapes and ownership, end to end:
//!
//! ```text
//!  GPU worker                     shared view                  sampler
//!  logits [B, V] row-major ──►  shard_row_major  ──►  ShardedLogits
//!                                t RankSlices, each a           │
//!                                vocab-major [V/t × B] slab     ▼
//!                                in an Arc'd buffer     get(v, b) walks the
//!                                (the shared-mem region) slices, no concat
//! ```
//!
//! [`Tensor2`] is the owned row-major building block; [`shard_row_major`]
//! transposes once to vocabulary-major and exposes `t` rank-local
//! [`RankSlice`]s over reference-counted buffers, modelling the per-rank
//! shared-memory slabs. Every sampler clones the same [`ShardedLogits`]
//! and reads only its owned sequences' columns, so an iteration's logits
//! are written once and read `m` times with zero copies — the property the
//! ring protocol ([`crate::ringbuf`]) is built around.

use std::sync::Arc;

/// Owned row-major 2-D f32 tensor (`rows × cols`, index = r*cols + c).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor2 {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Tensor2 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Tensor2 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Tensor2 { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }
    /// Contiguous row slice.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Out-of-place transpose (used by workers when producing the
    /// vocabulary-major layout; blocked for cache friendliness).
    pub fn transposed(&self) -> Tensor2 {
        const BLK: usize = 32;
        let mut out = Tensor2::zeros(self.cols, self.rows);
        for rb in (0..self.rows).step_by(BLK) {
            for cb in (0..self.cols).step_by(BLK) {
                for r in rb..(rb + BLK).min(self.rows) {
                    for c in cb..(cb + BLK).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }
}

/// One TP rank's vocabulary-major logits slice `[v_shard × B]`, stored in a
/// shared, reference-counted buffer (the "shared-memory region"). Element
/// `(v_local, b)` lives at `offset + v_local*batch + b`.
#[derive(Clone)]
pub struct RankSlice {
    buf: Arc<Vec<f32>>,
    offset: usize,
    pub v_shard: usize,
    pub batch: usize,
}

impl RankSlice {
    pub fn new(buf: Arc<Vec<f32>>, offset: usize, v_shard: usize, batch: usize) -> Self {
        assert!(offset + v_shard * batch <= buf.len(), "slice out of bounds");
        RankSlice { buf, offset, v_shard, batch }
    }

    /// Build from an owned vocab-major vec (tests, single-rank paths).
    pub fn from_vec(data: Vec<f32>, v_shard: usize, batch: usize) -> Self {
        assert_eq!(data.len(), v_shard * batch);
        RankSlice { buf: Arc::new(data), offset: 0, v_shard, batch }
    }

    #[inline]
    pub fn get(&self, v_local: usize, b: usize) -> f32 {
        debug_assert!(v_local < self.v_shard && b < self.batch);
        self.buf[self.offset + v_local * self.batch + b]
    }

    /// The contiguous row for one local vocab id (all sequences).
    pub fn vocab_row(&self, v_local: usize) -> &[f32] {
        let start = self.offset + v_local * self.batch;
        &self.buf[start..start + self.batch]
    }
}

/// Zero-copy full-vocabulary view over `t` rank-local slices (workflow step
/// ④): logically a `V × B` matrix made of vertical `V/t` slices. Samplers
/// iterate a sequence's logits across the full vocabulary without ever
/// materializing the concatenation.
#[derive(Clone)]
pub struct ShardedLogits {
    slices: Vec<RankSlice>,
    /// Cumulative vocab offsets; starts[r] = global vocab id of slice r's row 0.
    starts: Vec<usize>,
    vocab: usize,
    batch: usize,
}

impl ShardedLogits {
    pub fn new(slices: Vec<RankSlice>) -> Self {
        assert!(!slices.is_empty(), "need at least one rank slice");
        let batch = slices[0].batch;
        assert!(slices.iter().all(|s| s.batch == batch), "batch mismatch across ranks");
        let mut starts = Vec::with_capacity(slices.len());
        let mut vocab = 0;
        for s in &slices {
            starts.push(vocab);
            vocab += s.v_shard;
        }
        ShardedLogits { slices, starts, vocab, batch }
    }

    /// Single-rank (unsharded) logits from a row-major `[B × V]` tensor —
    /// transposes once, as the GPU worker does in workflow step ②.
    pub fn from_row_major(logits: &Tensor2) -> Self {
        let t = logits.transposed(); // [V × B]
        let (v, b) = (t.rows(), t.cols());
        Self::new(vec![RankSlice::from_vec(t.into_vec(), v, b)])
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }
    pub fn batch(&self) -> usize {
        self.batch
    }
    pub fn num_shards(&self) -> usize {
        self.slices.len()
    }

    /// Logit for (global vocab id, sequence).
    #[inline]
    pub fn get(&self, v: usize, b: usize) -> f32 {
        let r = match self.starts.binary_search(&v) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        self.slices[r].get(v - self.starts[r], b)
    }

    /// Visit all logits of sequence `b` in vocab order: `f(global_v, logit)`.
    /// This is the sampler's O(V) streaming scan; it touches each rank slice
    /// contiguously along its vocab rows (stride = batch).
    #[inline]
    pub fn for_each_logit(&self, b: usize, mut f: impl FnMut(usize, f32)) {
        debug_assert!(b < self.batch);
        for (r, s) in self.slices.iter().enumerate() {
            let base = self.starts[r];
            let start = s.offset + b;
            let buf = &s.buf[..];
            for v_local in 0..s.v_shard {
                // element (v_local, b) at offset + v_local*batch + b
                f(base + v_local, buf[start + v_local * s.batch]);
            }
        }
    }

    /// Gather sequence `b`'s logits for an explicit id list (hot-set reads).
    #[inline]
    pub fn gather(&self, b: usize, ids: &[u32], out: &mut Vec<f32>) {
        out.clear();
        out.reserve(ids.len());
        if self.slices.len() == 1 {
            let s = &self.slices[0];
            let base = s.offset + b;
            for &v in ids {
                out.push(s.buf[base + (v as usize) * s.batch]);
            }
        } else {
            for &v in ids {
                out.push(self.get(v as usize, b));
            }
        }
    }

    /// Materialize one sequence's full logits row (used by reference/oracle
    /// paths and the baseline full-V sampler; the SIMPLE fast path avoids it).
    pub fn materialize_row(&self, b: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.vocab);
        self.for_each_logit(b, |_, z| out.push(z));
        out
    }

    /// [`Self::materialize_row`] into a caller-owned scratch buffer — the
    /// vectorized dense kernels re-decide many columns per sampler thread
    /// and must not allocate per column.
    pub fn materialize_row_into(&self, b: usize, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.vocab);
        self.for_each_logit(b, |_, z| out.push(z));
    }
}

/// Split a row-major `[B × V]` logits tensor into `t` vocabulary-major rank
/// slices `[V/t × B]` — what the final-stage TP workers produce in steps
/// ②–③. The last rank takes the remainder when `t ∤ V`.
pub fn shard_row_major(logits: &Tensor2, t: usize) -> ShardedLogits {
    assert!(t >= 1);
    let (b, v) = (logits.rows(), logits.cols());
    let per = v / t;
    assert!(per > 0, "more shards than vocab");
    let mut slices = Vec::with_capacity(t);
    for r in 0..t {
        let v0 = r * per;
        let v1 = if r == t - 1 { v } else { v0 + per };
        let vs = v1 - v0;
        // transpose the [B × vs] panel into vocab-major [vs × B]
        let mut data = vec![0.0f32; vs * b];
        for bi in 0..b {
            let row = logits.row(bi);
            for (vl, &z) in row[v0..v1].iter().enumerate() {
                data[vl * b + bi] = z;
            }
        }
        slices.push(RankSlice::from_vec(data, vs, b));
    }
    ShardedLogits::new(slices)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq_tensor(rows: usize, cols: usize) -> Tensor2 {
        Tensor2::from_vec(rows, cols, (0..rows * cols).map(|i| i as f32).collect())
    }

    #[test]
    fn tensor_indexing_and_rows() {
        let t = seq_tensor(3, 4);
        assert_eq!(t.get(0, 0), 0.0);
        assert_eq!(t.get(2, 3), 11.0);
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let t = seq_tensor(5, 7);
        let tt = t.transposed();
        assert_eq!(tt.rows(), 7);
        assert_eq!(tt.cols(), 5);
        for r in 0..5 {
            for c in 0..7 {
                assert_eq!(t.get(r, c), tt.get(c, r));
            }
        }
        assert_eq!(tt.transposed(), t);
    }

    #[test]
    fn sharded_view_matches_dense_all_shardings() {
        let b = 6;
        let v = 20;
        let t = seq_tensor(b, v);
        for shards in [1, 2, 3, 4, 5] {
            let sl = shard_row_major(&t, shards);
            assert_eq!(sl.vocab(), v);
            assert_eq!(sl.batch(), b);
            assert_eq!(sl.num_shards(), shards);
            for bi in 0..b {
                for vi in 0..v {
                    assert_eq!(
                        sl.get(vi, bi),
                        t.get(bi, vi),
                        "shards={shards} v={vi} b={bi}"
                    );
                }
            }
        }
    }

    #[test]
    fn for_each_logit_streams_in_vocab_order() {
        let t = seq_tensor(3, 10);
        let sl = shard_row_major(&t, 3); // 3,3,4 split
        for b in 0..3 {
            let mut ids = Vec::new();
            let mut vals = Vec::new();
            sl.for_each_logit(b, |v, z| {
                ids.push(v);
                vals.push(z);
            });
            assert_eq!(ids, (0..10).collect::<Vec<_>>());
            assert_eq!(vals, t.row(b));
        }
    }

    #[test]
    fn materialize_equals_row() {
        let t = seq_tensor(4, 9);
        let sl = shard_row_major(&t, 2);
        for b in 0..4 {
            assert_eq!(sl.materialize_row(b), t.row(b));
        }
    }

    #[test]
    fn gather_reads_requested_ids() {
        let t = seq_tensor(2, 12);
        for shards in [1, 3] {
            let sl = shard_row_major(&t, shards);
            let ids = [11u32, 0, 5, 5, 7];
            let mut out = Vec::new();
            sl.gather(1, &ids, &mut out);
            let expect: Vec<f32> = ids.iter().map(|&v| t.get(1, v as usize)).collect();
            assert_eq!(out, expect);
        }
    }

    #[test]
    fn from_row_major_single_shard() {
        let t = seq_tensor(3, 5);
        let sl = ShardedLogits::from_row_major(&t);
        assert_eq!(sl.num_shards(), 1);
        for b in 0..3 {
            assert_eq!(sl.materialize_row(b), t.row(b));
        }
    }

    #[test]
    fn rank_slice_shared_buffer_zero_copy() {
        // Two slices sharing one backing buffer — the shared-memory region.
        let buf = Arc::new((0..24).map(|i| i as f32).collect::<Vec<f32>>());
        let a = RankSlice::new(buf.clone(), 0, 3, 4); // [3x4] at offset 0
        let c = RankSlice::new(buf.clone(), 12, 3, 4); // [3x4] at offset 12
        assert_eq!(a.get(0, 0), 0.0);
        assert_eq!(c.get(0, 0), 12.0);
        assert_eq!(Arc::strong_count(&buf), 3);
        let sl = ShardedLogits::new(vec![a, c]);
        assert_eq!(sl.vocab(), 6);
        assert_eq!(sl.get(3, 0), 12.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor2::from_vec(2, 3, vec![0.0; 5]);
    }
}
