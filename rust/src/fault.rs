//! Chaos-injection fault plans (DESIGN.md §10).
//!
//! A [`FaultPlan`] is a deterministic schedule of injected failures —
//! "kill sampler *i* at engine iteration *t*", "kill replica *r* after the
//! router has admitted *n* requests", plus the legacy "poison a service
//! lock at iteration *t*" (now a clean worker kill — the lock-free service
//! has no poisonable hot-path mutex) — used by the `chaos` harness
//! scenario, `serve --chaos`, and the
//! fault-recovery tests. Injection points are keyed by deterministic
//! progress counters (plan iterations, routed-request counts), never wall
//! time, so a chaos run is reproducible.
//!
//! The recovery hard bar the plans exist to prove: for ANY plan, per-
//! sequence token streams are bit-identical to the fault-free run
//! (decisions are keyed by (seed, seq, iteration) and every recovery path
//! replays state through the same recompute-on-resume machinery that
//! preemption and the prefill→decode handoff use), no panic escapes the
//! service or the router, and no KV block or slot leaks.

/// One kind of injected failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Crash sampler worker `sampler` (a panic inside its thread). The
    /// service detects the corpse on the next collect, respawns the worker
    /// on the same ring, releases the dead incarnation's cell claims, and
    /// resubmits its unanswered shard messages; sequence state rebuilds
    /// lazily from the lock-free replay records.
    KillSampler { sampler: usize },
    /// Crash engine replica `replica` (a panic inside its worker thread).
    /// The router's failure sweep requeues its outstanding sequences onto
    /// survivors through `submit_resumed` (recompute from the last known
    /// prefix — streams stay bit-identical by deterministic replay).
    KillReplica { replica: usize },
    /// Legacy fault: poison a service mutex. The lock-free service no
    /// longer has a poisonable hot-path mutex, so the syntax stays
    /// accepted (`poison@<iter>` plans keep parsing and rendering) but the
    /// engine maps it to a clean kill of worker 0 — same recovery
    /// machinery, same determinism bar.
    PoisonLock,
}

/// One scheduled fault. `at` is a progress counter, not a time: for
/// [`FaultKind::KillSampler`] and [`FaultKind::PoisonLock`] it is the
/// engine's scheduling-plan iteration; for [`FaultKind::KillReplica`] it
/// is the number of requests the router has admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub at: u64,
    pub kind: FaultKind,
}

/// A deterministic schedule of injected failures. Cloned into the engine
/// (sampler faults) and the router (replica faults); each holder fires its
/// own events once as its progress counter passes `at`.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
    /// Per-event fired flag (parallel to `events`).
    fired: Vec<bool>,
}

impl FaultPlan {
    pub fn new(events: Vec<FaultEvent>) -> FaultPlan {
        let fired = vec![false; events.len()];
        FaultPlan { events, fired }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Append one event.
    pub fn push(&mut self, at: u64, kind: FaultKind) {
        self.events.push(FaultEvent { at, kind });
        self.fired.push(false);
    }

    /// Take every not-yet-fired event with `at <= progress` that matches
    /// `pick`, marking it fired. Each holder (engine vs router) passes the
    /// filter for the fault kinds it owns.
    pub fn take_due(
        &mut self,
        progress: u64,
        pick: impl Fn(&FaultKind) -> bool,
    ) -> Vec<FaultKind> {
        let mut due = Vec::new();
        for (i, e) in self.events.iter().enumerate() {
            if !self.fired[i] && e.at <= progress && pick(&e.kind) {
                self.fired[i] = true;
                due.push(e.kind);
            }
        }
        due
    }

    /// Split into (engine-level plan, router-level plan): sampler kills and
    /// lock poisons fire inside the engine loop; replica kills fire in the
    /// router. Each side gets a plan holding only its own events.
    pub fn split(&self) -> (FaultPlan, FaultPlan) {
        let (mut engine, mut router) = (Vec::new(), Vec::new());
        for e in &self.events {
            match e.kind {
                FaultKind::KillReplica { .. } => router.push(*e),
                _ => engine.push(*e),
            }
        }
        (FaultPlan::new(engine), FaultPlan::new(router))
    }

    /// Parse a plan spec: comma-separated events of the forms
    /// `sampler:<id>@<iter>`, `replica:<id>@<n>`, `poison@<iter>`.
    /// E.g. `sampler:0@5,replica:1@8,poison@3`.
    pub fn parse(spec: &str) -> crate::Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (head, at) = part
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("fault `{part}`: missing `@<when>`"))?;
            let at: u64 = at
                .parse()
                .map_err(|_| anyhow::anyhow!("fault `{part}`: bad trigger `{at}`"))?;
            let kind = match head.split_once(':') {
                Some(("sampler", id)) => FaultKind::KillSampler {
                    sampler: id.parse().map_err(|_| {
                        anyhow::anyhow!("fault `{part}`: bad sampler id `{id}`")
                    })?,
                },
                Some(("replica", id)) => FaultKind::KillReplica {
                    replica: id.parse().map_err(|_| {
                        anyhow::anyhow!("fault `{part}`: bad replica id `{id}`")
                    })?,
                },
                None if head == "poison" => FaultKind::PoisonLock,
                _ => anyhow::bail!(
                    "fault `{part}`: expected sampler:<id>@<iter>, \
                     replica:<id>@<n>, or poison@<iter>"
                ),
            };
            plan.push(at, kind);
        }
        Ok(plan)
    }

    /// Validate the plan against the deployment it will run in: every
    /// sampler id must be < `num_samplers` and every replica id <
    /// `replicas` (with at least 2 replicas, or the kill has no survivor
    /// to fail over to). A plan that cannot fire must error loudly at
    /// startup — a silently no-op injection makes a chaos gate vacuous.
    pub fn validate(&self, num_samplers: usize, replicas: usize) -> crate::Result<()> {
        for e in &self.events {
            match e.kind {
                FaultKind::KillSampler { sampler } => anyhow::ensure!(
                    sampler < num_samplers,
                    "chaos plan kills sampler {sampler} but only {num_samplers} \
                     sampler(s) exist"
                ),
                FaultKind::KillReplica { replica } => {
                    anyhow::ensure!(
                        replicas >= 2,
                        "chaos plan kills replica {replica} but a single-replica \
                         deployment has no survivor (use --replicas 2+)"
                    );
                    anyhow::ensure!(
                        replica < replicas,
                        "chaos plan kills replica {replica} but only {replicas} \
                         replica(s) exist"
                    );
                }
                FaultKind::PoisonLock => {}
            }
        }
        Ok(())
    }

    /// Render back to the `parse` spec format (for logs and reports).
    pub fn render(&self) -> String {
        self.events
            .iter()
            .map(|e| match e.kind {
                FaultKind::KillSampler { sampler } => {
                    format!("sampler:{sampler}@{}", e.at)
                }
                FaultKind::KillReplica { replica } => {
                    format!("replica:{replica}@{}", e.at)
                }
                FaultKind::PoisonLock => format!("poison@{}", e.at),
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_every_kind() {
        let plan = FaultPlan::parse("sampler:2@5, replica:1@8,poison@3").unwrap();
        assert_eq!(plan.events().len(), 3);
        assert_eq!(plan.render(), "sampler:2@5,replica:1@8,poison@3");
        assert_eq!(
            plan.events()[0],
            FaultEvent { at: 5, kind: FaultKind::KillSampler { sampler: 2 } }
        );
        assert_eq!(
            plan.events()[1],
            FaultEvent { at: 8, kind: FaultKind::KillReplica { replica: 1 } }
        );
        assert_eq!(plan.events()[2], FaultEvent { at: 3, kind: FaultKind::PoisonLock });
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultPlan::parse("sampler:0").is_err());
        assert!(FaultPlan::parse("sampler:x@3").is_err());
        assert!(FaultPlan::parse("gpu:0@3").is_err());
        assert!(FaultPlan::parse("poison@soon").is_err());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn take_due_fires_each_event_once_in_progress_order() {
        let mut plan = FaultPlan::parse("sampler:0@2,sampler:1@4,poison@2").unwrap();
        let all = |_: &FaultKind| true;
        assert!(plan.take_due(1, all).is_empty());
        let due = plan.take_due(2, all);
        assert_eq!(
            due,
            vec![FaultKind::KillSampler { sampler: 0 }, FaultKind::PoisonLock]
        );
        assert!(plan.take_due(3, all).is_empty(), "already fired");
        assert_eq!(plan.take_due(10, all), vec![FaultKind::KillSampler { sampler: 1 }]);
    }

    #[test]
    fn validate_rejects_unfireable_plans() {
        let plan = FaultPlan::parse("sampler:1@2,replica:1@4").unwrap();
        assert!(plan.validate(2, 2).is_ok());
        assert!(plan.validate(1, 2).is_err(), "sampler 1 of 1");
        assert!(plan.validate(2, 1).is_err(), "replica kill needs a survivor");
        let lone = FaultPlan::parse("replica:0@1").unwrap();
        assert!(lone.validate(4, 1).is_err(), "no survivor");
        assert!(lone.validate(4, 2).is_ok());
    }

    #[test]
    fn split_partitions_engine_and_router_events() {
        let plan = FaultPlan::parse("sampler:0@1,replica:1@2,poison@3").unwrap();
        let (engine, router) = plan.split();
        assert_eq!(engine.events().len(), 2);
        assert_eq!(router.events().len(), 1);
        assert!(matches!(router.events()[0].kind, FaultKind::KillReplica { replica: 1 }));
    }
}
