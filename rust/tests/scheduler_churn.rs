//! Scheduler-churn integration: a bursty open-loop trace driven through the
//! preemptive continuous-batching scheduler *and* the sequence-parallel
//! decision service together — admissions, chunked prefill, KV-pressure
//! preemption, recompute-on-resume, and speculative-decoding windows —
//! without the PJRT runtime (no artifacts needed), asserting:
//!
//! - no slot or KV-block leaks after drain, for any sampler count `m`;
//! - token-stream determinism across sampler counts *and* across
//!   preemption (tight cache vs ample cache produce identical tokens);
//! - chunked-prefill budgets change timing, never tokens;
//! - verified speculative decode (`spec_k > 0`) commits bit-identical
//!   streams for any window size, including preemption landing
//!   mid-speculation (multi-token commits replay exactly).
//!
//! Logits come from [`LogitsGen::ctx_view`], keyed by (seq, decode_iter,
//! fed token) rather than batch position: a real model's logits depend on
//! the sequence's tokens, so a draft chain fed a rejected token sees
//! *different* logits than the true continuation — any bug that commits
//! past the accept point (or leaks rolled-back state) breaks the stream
//! comparisons loudly.

// Config structs are built by `default()` + field assignment (sweep-driver
// idiom); see the identical crate-level allow in lib.rs.
#![allow(clippy::field_reassign_with_default)]

use simple_serve::config::{DecisionVariant, EngineConfig, SamplerConfig};
use simple_serve::decision::draft::DraftProposer;
use simple_serve::decision::service::{ColumnMeta, IterationTask, SamplerService};
use simple_serve::decision::SeqHandle;
use simple_serve::engine::{Engine, KvAllocator, Scheduler, SchedulerConfig, SyntheticRuntime};
use simple_serve::harness::measure::{chain_views, LogitsGen};
use simple_serve::workload::{self, TraceConfig, TrafficPattern};
use std::collections::HashMap;
use std::sync::Arc;

const VOCAB: usize = 256;
const SLOTS: usize = 4;
const MAX_SEQ: usize = 96;
const N_REQ: usize = 30;

struct ChurnResult {
    streams: HashMap<u64, Vec<u32>>,
    preemptions: u64,
    spec_accepted: u64,
    spec_proposed: u64,
}

/// Drive the burst trace to drain through scheduler + service, speculating
/// `spec_k` draft tokens per decode iteration (0 = plain decode).
fn run_churn(m: usize, kv_blocks: usize, cfg: SchedulerConfig, spec_k: usize) -> ChurnResult {
    let gen = LogitsGen::new(VOCAB, 1.1, 17);
    let hot = gen.hot_vocab(32).into_arc();
    let proposer = DraftProposer::new();
    let svc_cfg = SamplerConfig {
        num_samplers: m,
        variant: DecisionVariant::Offloading,
        seed: 99,
        ..Default::default()
    };
    let svc = SamplerService::start(&svc_cfg, Some(hot), MAX_SEQ);
    let mut sched =
        Scheduler::with_config(SLOTS, KvAllocator::new(kv_blocks, 8), MAX_SEQ, cfg);

    let mut trace = workload::generate(&TraceConfig::tiny(N_REQ, VOCAB));
    TrafficPattern::parse("burst").unwrap().stamp(&mut trace, 500.0, 3);
    for r in trace.requests {
        sched.submit(r);
    }

    let mut clock = 0.0f64;
    let mut iter = 0u64;
    let mut guard = 0u32;
    let mut spec_accepted = 0u64;
    let mut spec_proposed = 0u64;
    // The handle IS the registration: holding it keeps the replay record
    // live; dropping it after `retire` lets the pool reclaim.
    let mut handles: HashMap<u64, SeqHandle> = HashMap::new();
    while !sched.is_idle() {
        guard += 1;
        assert!(guard < 20_000, "scheduler+service stuck");
        clock += 0.01;
        let plan = sched.plan(clock);
        // register admissions; resumed sequences replay their output into
        // the owner sampler's history (recompute-on-resume). Look slots up
        // in the scheduler: a fresh admission may be prefill-paused and
        // absent from plan.slots.
        for &id in &plan.admitted {
            let seq = (0..SLOTS)
                .find_map(|s| sched.slot(s).filter(|q| q.request.id == id))
                .expect("admitted sequence in a slot");
            let h =
                svc.register_full(id, &seq.request.prompt, &seq.output, &seq.request.params, None);
            handles.insert(id, h);
        }
        let cols: Vec<_> = plan.slots.iter().filter(|p| p.needs_decision).collect();
        if cols.is_empty() {
            sched.advance();
            continue;
        }
        // Draft windows (clamped like the engine: the bonus token is the
        // last that can commit; the chain stays inside the KV shape).
        let drafts: Vec<Vec<u32>> = cols
            .iter()
            .map(|p| {
                let seq = sched.slot(p.slot).unwrap();
                let k = DraftProposer::clamp_window(
                    spec_k,
                    seq.request.max_new_tokens,
                    seq.output.len(),
                    MAX_SEQ,
                    p.position,
                );
                proposer.propose(
                    seq.request.params.seed,
                    VOCAB,
                    &seq.request.prompt,
                    &seq.output,
                    k,
                )
            })
            .collect();
        // Chain views: position j of a column is keyed by the token the
        // data plane fed there (shared convention: measure::chain_views).
        let col_keys: Vec<(u64, u64, u32)> = cols
            .iter()
            .map(|p| (p.seq_id, p.decode_iter, p.input_token))
            .collect();
        let views = chain_views(&gen, &col_keys, &drafts, 2);
        let columns: Vec<ColumnMeta> = cols
            .iter()
            .enumerate()
            .map(|(i, p)| ColumnMeta { col: i, seq_id: p.seq_id, iteration: p.decode_iter })
            .collect();
        let recs: Vec<Option<SeqHandle>> =
            columns.iter().map(|meta| handles.get(&meta.seq_id).cloned()).collect();
        svc.submit(IterationTask {
            iter,
            mb: 0,
            views,
            columns: Arc::new(columns),
            recs: Arc::new(recs),
            pre: Arc::new(Vec::new()),
            drafts: Arc::new(drafts),
        });
        let (decisions, _busy) = svc.collect(iter, cols.len());
        assert_eq!(decisions.len(), cols.len(), "every column decided");
        iter += 1;
        for (ci, seq_id, verdict) in decisions {
            let slot = cols[ci].slot;
            // a commit earlier in this loop may have preempted this slot's
            // sequence: its verdict is discarded and re-derived
            // (identically) after resume
            if sched.slot(slot).map(|s| s.request.id) != Some(seq_id) {
                continue;
            }
            spec_accepted += verdict.accepted as u64;
            spec_proposed += verdict.proposed as u64;
            let out = sched.commit_multi(slot, &verdict.tokens);
            for (_, vid) in out.preempted {
                if let Some(h) = handles.remove(&vid) {
                    svc.retire(&h);
                }
            }
            if let Some(fid) = out.finished {
                if let Some(h) = handles.remove(&fid) {
                    svc.retire(&h);
                }
            }
        }
        sched.advance();
    }

    // drain invariants: nothing running, nothing leaked
    assert_eq!(sched.running_len(), 0);
    assert_eq!(sched.waiting_len(), 0);
    assert_eq!(sched.kv.used_blocks(), 0, "KV blocks leaked after drain");
    sched.kv.check_invariants().unwrap();

    let mut streams = HashMap::new();
    for f in sched.take_finished() {
        streams.insert(f.request.id, f.output);
    }
    svc.shutdown();
    ChurnResult {
        streams,
        preemptions: sched.preemption_count(),
        spec_accepted,
        spec_proposed,
    }
}

/// Tight cache: 4 slots each hold ≥1 of 5 blocks, so any block-boundary
/// crossing at full occupancy must evict (max single-sequence need is 3
/// blocks, so a lone sequence always fits — no livelock).
const TIGHT_KV: usize = 5;
/// Ample cache: never preempts.
const AMPLE_KV: usize = 64;

#[test]
fn burst_churn_drains_without_leaks_for_any_sampler_count() {
    for m in [1usize, 2, 5] {
        let res = run_churn(m, TIGHT_KV, SchedulerConfig::default(), 0);
        assert_eq!(res.streams.len(), N_REQ, "m={m}: all requests finished");
        assert!(res.preemptions > 0, "m={m}: tight cache must churn");
        // every request produced exactly its target token count
        let trace = workload::generate(&TraceConfig::tiny(N_REQ, VOCAB));
        for (r, &olen) in trace.requests.iter().zip(&trace.output_lens) {
            assert_eq!(
                res.streams[&r.id].len(),
                olen,
                "m={m}: request {} token count",
                r.id
            );
        }
    }
}

#[test]
fn token_streams_invariant_to_sampler_count_under_preemption() {
    // §5.1 determinism, now under admit/preempt/resume churn: m=1 and m=3
    // partition sequences across samplers differently AND interleave
    // preemptions differently-owned state — the streams must not change.
    let a = run_churn(1, TIGHT_KV, SchedulerConfig::default(), 0);
    let b = run_churn(3, TIGHT_KV, SchedulerConfig::default(), 0);
    assert!(a.preemptions > 0 && b.preemptions > 0);
    assert_eq!(a.streams, b.streams);
}

#[test]
fn token_streams_invariant_to_preemption_itself() {
    // The same trace with an ample cache (no preemption at all) must
    // produce byte-identical streams: eviction + recompute-on-resume is
    // invisible in the tokens, visible only in latency.
    let tight = run_churn(2, TIGHT_KV, SchedulerConfig::default(), 0);
    let ample = run_churn(2, AMPLE_KV, SchedulerConfig::default(), 0);
    assert!(tight.preemptions > 0, "tight run must actually preempt");
    assert_eq!(ample.preemptions, 0, "ample run must not preempt");
    assert_eq!(tight.streams, ample.streams);
}

#[test]
fn chunked_prefill_budget_changes_timing_not_tokens() {
    let budgeted = SchedulerConfig {
        prefill_token_budget: 2,
        max_prefill_chunk: 1,
        ..SchedulerConfig::default()
    };
    let a = run_churn(2, AMPLE_KV, budgeted, 0);
    let b = run_churn(2, AMPLE_KV, SchedulerConfig::default(), 0);
    assert_eq!(a.streams, b.streams, "budget must only reshape the schedule");
}

#[test]
fn multi_token_chunks_preserve_streams() {
    // Simulator-style multi-token prefill chunks (budget 8, chunk cap 4)
    // feed prompts in few iterations; decisions still land exactly on the
    // last known token, so the streams match the single-token schedule.
    let chunky = SchedulerConfig {
        prefill_token_budget: 8,
        max_prefill_chunk: 4,
        ..SchedulerConfig::default()
    };
    let a = run_churn(2, AMPLE_KV, chunky, 0);
    let b = run_churn(2, AMPLE_KV, SchedulerConfig::default(), 0);
    assert_eq!(a.streams, b.streams);
}

// ---- speculative decoding under churn ----

#[test]
fn spec_decode_streams_bit_identical_for_any_k_and_m() {
    // Verified speculation is invisible in the tokens across window sizes
    // AND sampler counts, under the full admit/commit/retire machinery.
    let baseline = run_churn(1, AMPLE_KV, SchedulerConfig::default(), 0);
    for (m, k) in [(1usize, 2usize), (2, 2), (4, 4), (2, 3)] {
        let spec = run_churn(m, AMPLE_KV, SchedulerConfig::default(), k);
        assert_eq!(spec.streams, baseline.streams, "m={m} k={k}");
        assert!(
            spec.spec_proposed > 0,
            "m={m} k={k}: windows must actually speculate"
        );
        assert!(spec.spec_accepted <= spec.spec_proposed);
    }
}

#[test]
fn preemption_mid_speculation_replays_multi_token_commits_exactly() {
    // The satellite: preemption landing mid-speculation (multi-token
    // commits triggering KV-pressure evictions, including of the
    // committing sequence itself) must replay exactly — no KV leak (the
    // drain invariants inside run_churn), deterministic resume, streams
    // identical to the ample-cache spec run AND to plain decode.
    let plain = run_churn(2, AMPLE_KV, SchedulerConfig::default(), 0);
    let spec_ample = run_churn(2, AMPLE_KV, SchedulerConfig::default(), 3);
    let spec_tight = run_churn(2, TIGHT_KV, SchedulerConfig::default(), 3);
    assert!(spec_tight.preemptions > 0, "tight cache must preempt mid-spec");
    assert_eq!(spec_ample.preemptions, 0);
    assert_eq!(spec_tight.streams, spec_ample.streams);
    assert_eq!(spec_tight.streams, plain.streams);
}

// ---- pipelined executor (in-flight microbatches, two-phase commit) ----

/// Drive the real engine over the synthetic data plane (closed loop).
/// `kv_blocks = 0` sizes the cache ample (never preempts); a small value
/// over-commits it so commits evict slots of *other* microbatches while
/// those still have un-reaped in-flight decisions. `chaos` is a
/// `FaultPlan::parse` spec ("" = fault-free); with faults the run also
/// asserts the drain left no slot or KV-block behind and that recovery
/// actually fired.
fn chaos_engine_run(
    n_mb: usize,
    overlap: bool,
    kv_blocks: usize,
    spec_k: usize,
    chaos: &str,
) -> (HashMap<u64, Vec<u32>>, u64) {
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = 2;
    cfg.sampler.seed = 41;
    cfg.n_microbatches = n_mb;
    cfg.overlap = overlap;
    cfg.spec_k = spec_k;
    cfg.kv_blocks = kv_blocks;
    cfg.idle_poll_us = 10;
    if !chaos.is_empty() {
        let (engine_faults, _) =
            simple_serve::fault::FaultPlan::parse(chaos).expect("chaos spec").split();
        cfg.faults = engine_faults;
    }
    let runtime = SyntheticRuntime::new(8, VOCAB, MAX_SEQ, 23);
    let mut engine = Engine::new(runtime, &cfg, None);
    let kv_free_at_start = engine.kv_free_blocks();
    let trace = workload::generate(&TraceConfig::tiny(20, VOCAB));
    for r in trace.requests {
        engine.submit(r);
    }
    engine.run_until_idle().expect("engine run (recovery, not failure)");
    let streams: HashMap<u64, Vec<u32>> = engine
        .take_finished()
        .into_iter()
        .map(|f| (f.request.id, f.output))
        .collect();
    let preemptions = engine.preemption_count();
    assert_eq!(engine.queue_depth(), 0, "no sequence left in a slot or queue");
    assert_eq!(
        engine.kv_free_blocks(),
        kv_free_at_start,
        "KV blocks leaked across the drain"
    );
    let (recorder, _) = engine.shutdown();
    if !chaos.is_empty() {
        assert!(recorder.recoveries() > 0, "chaos run must actually recover");
    }
    (streams, preemptions)
}

fn pipelined_engine_run(
    n_mb: usize,
    overlap: bool,
    kv_blocks: usize,
    spec_k: usize,
) -> (HashMap<u64, Vec<u32>>, u64) {
    chaos_engine_run(n_mb, overlap, kv_blocks, spec_k, "")
}

#[test]
fn preemption_fires_while_microbatch_has_unreaped_pending_commit() {
    // The two-phase-commit churn case: with overlap on and a tight KV
    // cache, applying microbatch A's pending commits evicts microbatch B's
    // slots while B still has an un-reaped in-flight decision. The stale
    // verdict must be discarded (identity guard) and the victim replayed —
    // streams bit-identical to the ample-cache synchronous run.
    let (sync_streams, sync_preempt) = pipelined_engine_run(1, false, 0, 0);
    assert_eq!(sync_streams.len(), 20, "all requests finish");
    assert_eq!(sync_preempt, 0, "ample cache must not preempt");
    // floor is max_seq/block + 1 = 7 blocks for 8 slots: crossing a block
    // boundary at full occupancy must evict
    let (tight_streams, tight_preempt) = pipelined_engine_run(2, true, 7, 0);
    assert!(tight_preempt > 0, "tight cache must preempt mid-flight");
    assert_eq!(tight_streams, sync_streams);
}

#[test]
fn overlapped_spec_decode_survives_preemption_churn() {
    // Everything at once: in-flight microbatches + overlap + speculative
    // windows + KV-pressure preemption landing mid-window. Same tokens.
    let (sync_streams, _) = pipelined_engine_run(1, false, 0, 0);
    let (spec_streams, spec_preempt) = pipelined_engine_run(2, true, 7, 3);
    assert!(spec_preempt > 0, "tight cache must preempt mid-spec");
    assert_eq!(spec_streams, sync_streams);
    let (quad_streams, _) = pipelined_engine_run(4, true, 0, 2);
    assert_eq!(quad_streams, sync_streams);
}

// ---- cluster layer (data-parallel replicas, DESIGN.md §9) ----

#[test]
fn cluster_kv_pressure_diverts_under_preemption_churn_and_streams_match() {
    // Replica KV caches sized at the preemption floor (7 blocks for 8
    // slots — crossing a block boundary at full occupancy must evict):
    // sequences preempt *while* the KvPressure policy routes each new
    // request toward the replica with more free blocks. The satellite's
    // churn case: diversion + preemption + recompute together must still
    // commit exactly the single-ample-engine streams.
    use simple_serve::cluster::{Cluster, ClusterConfig, RoutePolicy};
    let (want, ample_preempt) = pipelined_engine_run(1, false, 0, 0);
    assert_eq!(ample_preempt, 0);
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = 2;
    cfg.sampler.seed = 41;
    cfg.kv_blocks = 7;
    cfg.idle_poll_us = 10;
    let mut ccfg = ClusterConfig::default();
    ccfg.replicas = 2;
    ccfg.policy = RoutePolicy::KvPressure;
    let mut cluster = Cluster::start(&cfg, &ccfg, None, MAX_SEQ, |_id| {
        Ok(SyntheticRuntime::new(8, VOCAB, MAX_SEQ, 23))
    });
    let trace = workload::generate(&TraceConfig::tiny(20, VOCAB));
    cluster.run(trace.requests).expect("cluster run");
    let report = cluster.shutdown().expect("cluster shutdown");
    assert!(report.preemptions > 0, "tight caches must preempt mid-run");
    assert!(
        report.per_replica.iter().all(|r| r.summary.tokens > 0),
        "KvPressure must divert work to both replicas: {:?}",
        report
            .per_replica
            .iter()
            .map(|r| r.summary.tokens)
            .collect::<Vec<_>>()
    );
    let streams: HashMap<u64, Vec<u32>> = report
        .finished
        .iter()
        .map(|s| (s.request.id, s.output.clone()))
        .collect();
    assert_eq!(streams, want, "diversion + preemption must not change tokens");
}

// ---- fault recovery (DESIGN.md §10) ----

#[test]
fn sampler_crash_recovery_under_preemption_churn_leaks_nothing() {
    // A sampler killed mid-run — twice, different workers — while the
    // tight cache is preempting and re-admitting sequences: recovery must
    // replay the dead worker's owned state exactly (streams bit-identical
    // to the fault-free ample-cache run) and the drain must leave zero
    // slot or KV-block leaks (asserted inside chaos_engine_run).
    let (want, _) = pipelined_engine_run(1, false, 0, 0);
    let (got, preempt) = chaos_engine_run(1, false, 7, 0, "sampler:0@5,sampler:1@14");
    assert!(preempt > 0, "tight cache must churn under the faults");
    assert_eq!(got, want, "sampler crashes must not change tokens");
}

#[test]
fn sampler_crash_recovery_composes_with_overlap_and_speculation() {
    // The worst engine shape for recovery: in-flight microbatches with
    // reaped-but-unapplied verdicts, speculative windows mid-flight, and
    // a sampler kill landing among them — plus a legacy `poison@` event
    // (now a clean kill of worker 0) for good measure. Same tokens,
    // nothing leaked.
    let (want, _) = pipelined_engine_run(1, false, 0, 0);
    let (got, _) = chaos_engine_run(2, true, 0, 2, "sampler:1@6,poison@9");
    assert_eq!(got, want, "chaos under overlap+spec must not change tokens");
}

#[test]
fn replica_death_requeues_onto_survivor_and_streams_match() {
    // Kill replica 1 mid-burst: the router's failure sweep must requeue
    // its outstanding sequences onto replica 0 through the resume path —
    // every request still finishes, streams bit-identical to the single
    // ample engine, and the failover is visible in the report counters.
    use simple_serve::cluster::{Cluster, ClusterConfig, RoutePolicy};
    let (want, _) = pipelined_engine_run(1, false, 0, 0);
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = 2;
    cfg.sampler.seed = 41;
    cfg.idle_poll_us = 10;
    let mut ccfg = ClusterConfig::default();
    ccfg.replicas = 2;
    ccfg.policy = RoutePolicy::RoundRobin;
    let (_, router_faults) = simple_serve::fault::FaultPlan::parse("replica:1@6")
        .expect("chaos spec")
        .split();
    ccfg.faults = router_faults;
    let mut cluster = Cluster::start(&cfg, &ccfg, None, MAX_SEQ, |_id| {
        Ok(SyntheticRuntime::new(8, VOCAB, MAX_SEQ, 23))
    });
    let trace = workload::generate(&TraceConfig::tiny(20, VOCAB));
    cluster.run(trace.requests).expect("failover, not failure");
    let report = cluster.shutdown().expect("cluster shutdown");
    assert_eq!(report.failovers, 1, "exactly one replica death");
    assert!(report.requeued > 0, "the dead replica had outstanding work");
    assert_eq!(report.recorder.recoveries(), 1);
    let streams: HashMap<u64, Vec<u32>> = report
        .finished
        .iter()
        .map(|s| (s.request.id, s.output.clone()))
        .collect();
    assert_eq!(streams, want, "failover requeue must not change tokens");
    // the surviving replica carried the whole fleet's final state
    assert_eq!(report.per_replica.len(), 1, "dead replica skipped at join");
    assert_eq!(report.per_replica[0].id, 0);
}

#[test]
fn shared_pool_steals_across_replica_failover_requeue() {
    // Satellite: the lock-free shared pool under failover churn. Both
    // replicas submit into ONE sampler pool; replica 1 dies mid-burst and
    // the router purges its task namespace from the shared slot table,
    // then requeues its sequences onto replica 0 through the resume path.
    // The surviving replica now carries the whole fleet, so its shard
    // rings back up and the idle workers steal — verdicts for requeued
    // sequences are produced by whichever worker got there first. Streams
    // must still match the single ample engine bit-for-bit (decisions are
    // keyed by (seed, seq, iteration), never worker identity).
    use simple_serve::cluster::{Cluster, ClusterConfig, RoutePolicy};
    let (want, _) = pipelined_engine_run(1, false, 0, 0);
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = 2;
    cfg.sampler.seed = 41;
    cfg.idle_poll_us = 10;
    let mut ccfg = ClusterConfig::default();
    ccfg.replicas = 2;
    ccfg.policy = RoutePolicy::RoundRobin;
    ccfg.shared_samplers = true;
    let (_, router_faults) = simple_serve::fault::FaultPlan::parse("replica:1@6")
        .expect("chaos spec")
        .split();
    ccfg.faults = router_faults;
    let mut cluster = Cluster::start(&cfg, &ccfg, None, MAX_SEQ, |_id| {
        Ok(SyntheticRuntime::new(8, VOCAB, MAX_SEQ, 23))
    });
    let trace = workload::generate(&TraceConfig::tiny(20, VOCAB));
    cluster.run(trace.requests).expect("failover, not failure");
    let report = cluster.shutdown().expect("cluster shutdown");
    assert_eq!(report.failovers, 1, "exactly one replica death");
    assert!(report.requeued > 0, "the dead replica had outstanding work");
    let streams: HashMap<u64, Vec<u32>> = report
        .finished
        .iter()
        .map(|s| (s.request.id, s.output.clone()))
        .collect();
    assert_eq!(streams, want, "shared-pool failover must not change tokens");
}

// ---- prefix cache under churn (DESIGN.md §13) ----

/// Drive a conversation-tree trace (shared multi-block system prompts,
/// each turn extending its parent's history) through the engine on the
/// synthetic plane. Returns (streams, preemptions, prefix stats).
fn conv_engine_run(
    prefix_cache: bool,
    kv_blocks: usize,
) -> (HashMap<u64, Vec<u32>>, u64, simple_serve::engine::kvcache::PrefixStats) {
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = 2;
    cfg.sampler.seed = 41;
    cfg.kv_blocks = kv_blocks;
    cfg.idle_poll_us = 10;
    cfg.prefix_cache = prefix_cache;
    let runtime = SyntheticRuntime::new(4, VOCAB, MAX_SEQ, 23);
    let mut engine = Engine::new(runtime, &cfg, None);
    let kv_free_at_start = engine.kv_free_blocks();
    let mut ccfg = workload::ConvConfig::tiny(8, VOCAB);
    ccfg.system_len = 32; // 2 full 16-token blocks shared across convs
    ccfg.user_min = 4;
    ccfg.user_max = 8;
    ccfg.reply_min = 4;
    ccfg.reply_max = 8;
    ccfg.max_context = MAX_SEQ - 4;
    for r in workload::conversations(&ccfg).requests {
        engine.submit(r);
    }
    engine.run_until_idle().expect("engine run");
    let streams: HashMap<u64, Vec<u32>> = engine
        .take_finished()
        .into_iter()
        .map(|f| (f.request.id, f.output))
        .collect();
    let preemptions = engine.preemption_count();
    let stats = engine.prefix_stats();
    assert_eq!(engine.queue_depth(), 0, "no sequence left in a slot or queue");
    assert_eq!(
        engine.kv_free_blocks(),
        kv_free_at_start,
        "KV blocks leaked across the drain (a warm index must stay reclaimable)"
    );
    (streams, preemptions, stats)
}

#[test]
fn preempted_sequence_resumes_onto_partially_evicted_prefix() {
    // The satellite churn case: a KV pool tight enough that live sequences
    // preempt AND cached radix leaves get reclaimed mid-run. A preempted
    // sequence's resume admission then walks a chain whose tail has been
    // evicted — it shares what survives and recomputes only the rest.
    // Ground truth is the reuse-off ample-cache run: eviction depth is a
    // performance fact, never a token fact.
    let (want, _, _) = conv_engine_run(false, 0);
    // 10 blocks for 4 slots × up to 6 blocks/seq: over-committed at full
    // occupancy, while the largest single sequence (6 blocks) still fits —
    // churn without livelock.
    let (got, preemptions, stats) = conv_engine_run(true, 10);
    assert!(preemptions > 0, "tight cache must preempt");
    assert!(stats.evictions > 0, "pressure must reclaim cached leaves");
    assert!(stats.hits > 0, "admissions must actually share cached prefixes");
    assert_eq!(got, want, "evicted-prefix resume must not change tokens");
}

#[test]
fn spec_decode_composes_with_chunked_prefill_and_sampler_churn() {
    // Everything at once: chunked prefill budgets + speculation + tight KV
    // + different m. Still the same tokens.
    let chunky = SchedulerConfig {
        prefill_token_budget: 8,
        max_prefill_chunk: 4,
        ..SchedulerConfig::default()
    };
    let a = run_churn(3, TIGHT_KV, chunky.clone(), 2);
    let b = run_churn(1, AMPLE_KV, SchedulerConfig::default(), 0);
    assert_eq!(a.streams, b.streams);
    assert!(a.preemptions > 0);
}
