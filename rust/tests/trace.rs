//! Flight-recorder tracing: the observation-only bars (DESIGN.md §14).
//!
//! Three properties and one cross-check:
//! 1. Per-thread spans are well-nested (LIFO `B`/`E`, per-lane monotonic
//!    timestamps) under randomized nesting across threads — the structure
//!    Perfetto needs to render a lane.
//! 2. Ring overflow drops oldest-first and the surviving window still
//!    exports as valid, untorn Chrome-trace JSON.
//! 3. **The tracing hard bar**: per-sequence token streams are
//!    bit-identical with tracing on vs off, across spec_k × microbatches ×
//!    replicas × a chaos fault plan — tracing is pure observation.
//! 4. The trace-derived [`OverlapReport`] (forward/decide/collect-wait
//!    spans replayed through the Recorder arithmetic) matches the live
//!    Recorder of the same run: two accounting systems, one timeline.
//!
//! Tracing state (`trace::set_enabled`) and the event registry are
//! process-global, so every test here serializes on one mutex and clears
//! the rings before emitting.

// Config structs are built by `default()` + field assignment (sweep-driver
// idiom); see the identical crate-level allow in lib.rs.
#![allow(clippy::field_reassign_with_default)]

use simple_serve::cluster::{Cluster, ClusterConfig, RoutePolicy};
use simple_serve::config::{DecisionVariant, EngineConfig};
use simple_serve::engine::{Engine, Request, SyntheticRuntime};
use simple_serve::fault::FaultPlan;
use simple_serve::rng::Philox;
use simple_serve::trace::{self, export, Kind, Phase, TraceEvent, DEFAULT_RING_CAP};
use simple_serve::workload::{self, TraceConfig};
use std::collections::HashMap;
use std::sync::Mutex;

const VOCAB: usize = 2_048;
const MAX_SEQ: usize = 96;
const BATCH: usize = 4;
const PLANE_SEED: u64 = 53;

/// Serializes every test that flips the global trace gate or reads the
/// global registry. Poisoning is irrelevant — the guard protects no data.
static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    TRACE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// 1. Well-nested per-thread spans (property)
// ---------------------------------------------------------------------------

/// Walk one lane's events in emission order: `B`/`E` must be LIFO with
/// matching kind+args, timestamps per-lane monotonic, stack empty at the
/// end. Well-nested implies same-lane spans either nest or are disjoint —
/// never partially overlap.
fn assert_well_nested(events: &[TraceEvent]) {
    let mut lanes: HashMap<(u32, u32), Vec<&TraceEvent>> = HashMap::new();
    for ev in events {
        lanes.entry((ev.pid, ev.tid)).or_default().push(ev);
    }
    for ((pid, tid), evs) in lanes {
        let mut stack: Vec<(Kind, u64)> = Vec::new();
        let mut last_ts = 0u64;
        for ev in evs {
            assert!(
                ev.ts_ns >= last_ts,
                "lane {pid}/{tid}: timestamps went backwards"
            );
            last_ts = ev.ts_ns;
            match ev.ph {
                Phase::Begin => stack.push((ev.kind, ev.a)),
                Phase::End => {
                    let open = stack.pop().unwrap_or_else(|| {
                        panic!("lane {pid}/{tid}: E without a matching B")
                    });
                    assert_eq!(
                        open,
                        (ev.kind, ev.a),
                        "lane {pid}/{tid}: spans closed out of LIFO order"
                    );
                }
                Phase::Complete | Phase::Instant => {}
            }
        }
        assert!(stack.is_empty(), "lane {pid}/{tid}: unclosed spans");
    }
}

const SPAN_KINDS: [Kind; 4] =
    [Kind::EnginePlan, Kind::EngineCommit, Kind::SvcCollect, Kind::SchedChunk];

/// Emit a random span tree: RAII guards give stack discipline for free;
/// the property checks the *recorded* events still have it after the ring
/// and the merge-sort in `snapshot_events`.
fn random_spans(rng: &mut Philox, depth: usize) {
    let n = 1 + rng.next_below(3) as usize;
    for _ in 0..n {
        let kind = SPAN_KINDS[rng.next_below(SPAN_KINDS.len() as u64) as usize];
        let _g = trace::span(kind, rng.next_below(1000), 0);
        if rng.next_f64() < 0.4 {
            trace::instant(Kind::KvHit, rng.next_below(1000), 0);
        }
        if depth < 4 && rng.next_f64() < 0.6 {
            random_spans(rng, depth + 1);
        }
    }
}

#[test]
fn prop_per_thread_spans_are_well_nested() {
    let _g = locked();
    let mut next_tid = 500u32;
    for case in 0..8u64 {
        trace::clear();
        trace::set_enabled(true);
        std::thread::scope(|scope| {
            for t in 0..3u64 {
                // unique lane per thread: two writers on one (pid, tid)
                // would interleave B/E and break the per-lane property
                let tid = next_tid;
                next_tid += 1;
                scope.spawn(move || {
                    trace::register_thread(0, tid);
                    let mut rng = Philox::substream(0xA11CE ^ case, case * 31 + t);
                    random_spans(&mut rng, 0);
                });
            }
        });
        trace::set_enabled(false);
        let events = trace::snapshot_events();
        assert!(!events.is_empty(), "case {case}: no events recorded");
        assert_well_nested(&events);
    }
    trace::clear();
}

// ---------------------------------------------------------------------------
// 2. Ring overflow: oldest-first, export stays valid
// ---------------------------------------------------------------------------

#[test]
fn ring_overflow_drops_oldest_first_and_export_survives() {
    let _g = locked();
    trace::clear();
    trace::set_enabled(true);
    const LANE: u32 = 7_777;
    let extra = 777usize;
    let total = DEFAULT_RING_CAP + extra;
    std::thread::spawn(move || {
        trace::register_thread(0, LANE);
        for i in 0..total {
            trace::instant(Kind::KvHit, i as u64, 0xFEED);
        }
    })
    .join()
    .unwrap();
    trace::set_enabled(false);

    let events: Vec<TraceEvent> = trace::snapshot_events()
        .into_iter()
        .filter(|e| e.tid == LANE)
        .collect();
    // the ring retains exactly the newest `capacity` records...
    assert_eq!(events.len(), DEFAULT_RING_CAP);
    // ...which are the LAST pushed, still in order and untorn
    for (i, ev) in events.iter().enumerate() {
        assert_eq!(ev.a, (extra + i) as u64, "overflow did not drop oldest-first");
        assert_eq!(ev.b, 0xFEED, "record torn by overwrite");
        assert_eq!(ev.kind, Kind::KvHit);
    }
    assert!(
        trace::dropped_events() >= extra as u64,
        "overwritten records must be accounted as dropped"
    );

    // the surviving window exports as valid JSON (schema + roundtrip)
    let j = export::chrome_json(&events);
    let list = j.get("traceEvents").as_arr().unwrap();
    // 1 process-name + 1 thread-name metadata record + the events
    assert_eq!(list.len(), DEFAULT_RING_CAP + 2);
    let reparsed = simple_serve::util::json::Json::parse(&j.to_string_pretty())
        .expect("export must stay parseable after overflow");
    assert_eq!(reparsed, j);
    trace::clear();
}

// ---------------------------------------------------------------------------
// 2b. Ring lifecycle: no allocation when off, recycled across spawns
// ---------------------------------------------------------------------------

#[test]
fn tracing_off_threads_allocate_no_rings() {
    let _g = locked();
    trace::set_enabled(false);
    let before = trace::allocated_rings();
    std::thread::spawn(|| {
        trace::register_thread(0, 8_888);
        trace::instant(Kind::KvHit, 1, 2);
        drop(trace::span(Kind::EnginePlan, 0, 0));
    })
    .join()
    .unwrap();
    assert_eq!(
        trace::allocated_rings(),
        before,
        "a thread that never emits with tracing on must not allocate a ring"
    );
}

#[test]
fn rings_are_recycled_across_sequential_thread_spawns() {
    let _g = locked();
    trace::clear();
    trace::set_enabled(true);
    let before = trace::allocated_rings();
    for i in 0..32u32 {
        std::thread::spawn(move || {
            trace::register_thread(0, 9_000 + i);
            trace::instant(Kind::KvHit, i as u64, 0);
        })
        .join()
        .unwrap();
    }
    trace::set_enabled(false);
    let after = trace::allocated_rings();
    // each thread exits (releasing its ring) before the next spawns, so at
    // most one new ring is ever allocated — the rest reuse it
    assert!(
        after <= before + 1,
        "sequential spawns must recycle rings, not grow the registry: \
         {before} -> {after}"
    );
    trace::clear();
}

// ---------------------------------------------------------------------------
// 3. The hard bar: tracing on/off never changes a token stream
// ---------------------------------------------------------------------------

fn digest_run(replicas: usize, m: usize, spec_k: usize, n_mb: usize, plan: &str) -> u64 {
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = m;
    cfg.sampler.seed = 0xD1FF;
    cfg.spec_k = spec_k;
    cfg.n_microbatches = n_mb;
    cfg.overlap = n_mb > 1;
    cfg.idle_poll_us = 20;
    let mut ccfg = ClusterConfig::default();
    ccfg.replicas = replicas;
    ccfg.policy = RoutePolicy::RoundRobin;
    ccfg.shared_samplers = replicas > 1;
    ccfg.idle_poll_us = 20;
    if !plan.is_empty() {
        let parsed = FaultPlan::parse(plan).expect("fault plan parses");
        let (engine_faults, router_faults) = parsed.split();
        cfg.faults = engine_faults;
        ccfg.faults = router_faults;
    }
    let trace_reqs: Vec<Request> = workload::generate(&TraceConfig::tiny(8, VOCAB)).requests;
    let mut cluster = Cluster::start(&cfg, &ccfg, None, MAX_SEQ, |_id| {
        Ok(SyntheticRuntime::new(BATCH, VOCAB, MAX_SEQ, PLANE_SEED))
    });
    cluster.run(trace_reqs).expect("run");
    cluster.shutdown().expect("shutdown").stream_digest()
}

#[test]
fn differential_digests_identical_tracing_on_vs_off() {
    let _g = locked();
    for replicas in [1usize, 2] {
        for spec_k in [0usize, 2] {
            for n_mb in [1usize, 2] {
                for fault in [false, true] {
                    let plan = match (fault, replicas) {
                        (false, _) => "",
                        (true, 1) => "sampler:0@4",
                        (true, _) => "sampler:0@3,replica:1@6",
                    };
                    trace::set_enabled(false);
                    let off = digest_run(replicas, 2, spec_k, n_mb, plan);
                    trace::clear();
                    trace::set_enabled(true);
                    let on = digest_run(replicas, 2, spec_k, n_mb, plan);
                    trace::set_enabled(false);
                    trace::clear();
                    assert_eq!(
                        off, on,
                        "tracing changed tokens at r{replicas} k{spec_k} \
                         mb{n_mb} plan `{plan}` — it must be pure observation"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 4. Trace-derived overlap accounting matches the live Recorder
// ---------------------------------------------------------------------------

#[test]
fn overlap_report_from_trace_matches_live_recorder() {
    let _g = locked();
    trace::clear();
    trace::set_enabled(true);

    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = 2;
    cfg.sampler.seed = 0x0B5;
    cfg.spec_k = 2;
    cfg.n_microbatches = 2;
    cfg.overlap = true;
    cfg.idle_poll_us = 20;
    let runtime = SyntheticRuntime::new(BATCH, VOCAB, MAX_SEQ, PLANE_SEED);
    let mut engine = Engine::new(runtime, &cfg, None);
    for r in workload::generate(&TraceConfig::tiny(10, VOCAB)).requests {
        engine.submit(r);
    }
    engine.run_until_idle().expect("engine run");
    let _ = engine.take_finished();
    let (recorder, _stats) = engine.shutdown();
    trace::set_enabled(false);

    let events = trace::snapshot_events();
    assert!(
        events.iter().any(|e| e.kind == Kind::EngineForward),
        "no forward spans captured"
    );
    assert!(
        events.iter().any(|e| e.kind == Kind::SvcDecide),
        "no decide spans captured"
    );
    let derived = export::overlap_report_from_trace(&events);
    let live = recorder.overlap_report();
    trace::clear();

    // Both accountings saw the same endpoints (shared epoch, shared
    // measurement sites); the only daylight is the ns truncation in
    // `complete_s` — ≤ ±1 ns per interval, so even thousands of intervals
    // stay orders of magnitude under this bound.
    let close = |got: f64, want: f64, what: &str| {
        assert!(
            (got - want).abs() <= 5e-5,
            "{what}: trace-derived {got} vs live {want}"
        );
    };
    assert!(live.gpu_busy_s > 0.0, "run recorded no GPU stage time");
    assert!(live.decision_busy_s > 0.0, "run recorded no decision time");
    close(derived.gpu_busy_s, live.gpu_busy_s, "gpu_busy_s");
    close(derived.decision_busy_s, live.decision_busy_s, "decision_busy_s");
    close(derived.hidden_s, live.hidden_s, "hidden_s");
    close(derived.exposed_wait_s, live.exposed_wait_s, "exposed_wait_s");
}
