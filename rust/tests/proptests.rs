//! Property-based tests over the decision plane's invariants.
//!
//! proptest is unavailable offline, so a minimal driver (`props!`) sweeps
//! deterministic Philox-generated random cases; failures print the case
//! seed for reproduction. Each property runs dozens-to-hundreds of cases.

// Config structs are built by `default()` + field assignment (sweep-driver
// idiom); see the identical crate-level allow in lib.rs.
#![allow(clippy::field_reassign_with_default)]

use simple_serve::cluster::{Cluster, ClusterConfig, RoutePolicy};
use simple_serve::config::{DecisionVariant, EngineConfig, SamplerConfig};
use simple_serve::decision::draft::DraftProposer;
use simple_serve::decision::filter::{self, Truncated};
use simple_serve::decision::penalties::{apply_penalties_dense, BatchHistory, SeqHistory};
use simple_serve::decision::service::{ColumnMeta, IterationTask, SamplerService};
use simple_serve::decision::shvs::{Precompute, ShvsSampler};
use simple_serve::decision::verify::{verify_window, GrammarSlot};
use simple_serve::decision::{
    DecisionPipeline, DenseKernel, HotVocab, KernelBackend, SamplingParams, SeqHandle,
};
use simple_serve::engine::{Engine, KvAllocator, Request, SyntheticRuntime};
use simple_serve::fault::{FaultKind, FaultPlan};
use simple_serve::harness::measure::{chain_views, LogitsGen};
use simple_serve::metrics::stats::total_variation_distance;
use simple_serve::rng::Philox;
use simple_serve::tensor::{shard_row_major, Tensor2};
use std::sync::Arc;

/// Run `n` cases of a property, feeding each a per-case RNG.
fn props(name: &str, n: u64, mut prop: impl FnMut(&mut Philox)) {
    for case in 0..n {
        let mut rng = Philox::substream(0x5EED ^ case, case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property {name} failed at case {case}");
            std::panic::resume_unwind(e);
        }
    }
}

fn random_params(rng: &mut Philox, vocab: usize) -> SamplingParams {
    SamplingParams {
        temperature: 0.3 + rng.next_f64() as f32 * 1.5,
        top_k: if rng.next_f64() < 0.5 {
            1 + rng.next_below(vocab as u64 / 2) as usize
        } else {
            0
        },
        top_p: if rng.next_f64() < 0.5 {
            0.5 + rng.next_f64() as f32 * 0.5
        } else {
            1.0
        },
        min_p: if rng.next_f64() < 0.3 {
            rng.next_f64() as f32 * 0.1
        } else {
            0.0
        },
        repetition_penalty: 1.0 + rng.next_f64() as f32 * 0.5,
        presence_penalty: rng.next_f64() as f32 * 0.5,
        frequency_penalty: rng.next_f64() as f32 * 0.3,
        ..Default::default()
    }
}

fn random_logits(rng: &mut Philox, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_normal() as f32 * 2.0).collect()
}

/// Masked softmax oracle over the truncated set.
fn dist_of(t: &Truncated, vocab: usize) -> Vec<f64> {
    let mut d = vec![0.0; vocab];
    for (i, &id) in t.ids.iter().enumerate() {
        d[id as usize] = t.prob(i);
    }
    d
}

#[test]
fn prop_truncation_first_equals_sort_based() {
    props("truncate==sort", 150, |rng| {
        let vocab = 16 + rng.next_below(200) as usize;
        let logits = random_logits(rng, vocab);
        let params = random_params(rng, vocab);
        let pairs: Vec<(u32, f32)> =
            logits.iter().enumerate().map(|(i, &z)| (i as u32, z)).collect();
        let a = filter::truncate(pairs.clone(), &params);
        let b = filter::truncate_sort_based(pairs, &params);
        let da = dist_of(&a, vocab);
        let db = dist_of(&b, vocab);
        let tvd = total_variation_distance(&da, &db);
        assert!(tvd < 1e-9, "tvd {tvd} params {params:?}");
    });
}

#[test]
fn prop_truncated_probs_normalized_and_supported() {
    props("truncate normalized", 150, |rng| {
        let vocab = 8 + rng.next_below(500) as usize;
        let logits = random_logits(rng, vocab);
        let params = random_params(rng, vocab);
        let pairs: Vec<(u32, f32)> =
            logits.iter().enumerate().map(|(i, &z)| (i as u32, z)).collect();
        let t = filter::truncate(pairs, &params);
        assert!(!t.is_empty());
        let total: f64 = (0..t.len()).map(|i| t.prob(i)).sum();
        assert!((total - 1.0).abs() < 1e-9, "total {total}");
        if params.top_k > 0 {
            assert!(t.len() <= params.top_k);
        }
        // every kept id is within vocab and unique
        let mut ids = t.ids.clone();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), t.ids.len());
        assert!(ids.iter().all(|&i| (i as usize) < vocab));
    });
}

#[test]
fn prop_incremental_histogram_equals_rebuild() {
    props("hist incremental==rebuild", 100, |rng| {
        let batch = 1 + rng.next_below(4) as usize;
        let vocab = 64u64;
        let prompts: Vec<Vec<u32>> = (0..batch)
            .map(|_| {
                (0..rng.next_below(10))
                    .map(|_| rng.next_below(vocab) as u32)
                    .collect()
            })
            .collect();
        let mut bh = BatchHistory::new(&prompts, 128);
        let steps = rng.next_below(40) as usize;
        for _ in 0..steps {
            let row: Vec<u32> =
                (0..batch).map(|_| rng.next_below(vocab) as u32).collect();
            bh.append_row(&row);
        }
        for b in 0..batch {
            let rebuilt = bh.rebuild(b);
            let total: u32 = rebuilt.values().sum();
            assert_eq!(total as usize, steps);
            for (&t, &c) in &rebuilt {
                assert_eq!(bh.seq(b).out_count(t), c);
            }
            // and the incremental one has no extra entries
            assert_eq!(bh.seq(b).out_len(), steps);
        }
    });
}

#[test]
fn prop_penalties_only_lower_seen_token_probability() {
    props("penalties lower seen", 100, |rng| {
        let vocab = 32 + rng.next_below(100) as usize;
        let logits = random_logits(rng, vocab);
        let params = SamplingParams {
            repetition_penalty: 1.0 + rng.next_f64() as f32,
            presence_penalty: rng.next_f64() as f32,
            frequency_penalty: rng.next_f64() as f32,
            ..Default::default()
        };
        let mut hist = SeqHistory::new(&[]);
        let seen = rng.next_below(vocab as u64) as u32;
        hist.append(seen);
        let mut penalized = logits.clone();
        apply_penalties_dense(&mut penalized, &hist, &params);
        // softmax prob of the seen token must not increase
        let p = |zs: &[f32], id: usize| {
            let m = zs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let s: f64 = zs.iter().map(|&z| ((z - m) as f64).exp()).sum();
            ((zs[id] - m) as f64).exp() / s
        };
        let before = p(&logits, seen as usize);
        let after = p(&penalized, seen as usize);
        assert!(after <= before + 1e-12, "seen {seen}: {before} -> {after}");
        // unseen tokens' logits unchanged
        for (i, (&a, &b)) in logits.iter().zip(&penalized).enumerate() {
            if i != seen as usize {
                assert_eq!(a, b);
            }
        }
    });
}

#[test]
fn prop_simd_truncation_bitwise_equals_scalar() {
    // The kernel differential property: for random logits × random filter
    // combinations × a lived-in history, the SIMD path's truncation keeps
    // IDENTICAL ids, bit-equal stable weights and weight sums, and samples
    // the identical token for the same Philox draw.
    props("simd truncate == scalar", 120, |rng| {
        let vocab = 16 + rng.next_below(400) as usize;
        let logits = random_logits(rng, vocab);
        let view = shard_row_major(
            &Tensor2::from_vec(1, vocab, logits),
            1 + rng.next_below(3) as usize,
        );
        let params = random_params(rng, vocab);
        let mut hist = SeqHistory::new(&[3]);
        for _ in 0..rng.next_below(6) {
            hist.append(rng.next_below(vocab as u64) as u32);
        }
        let mut scalar = DenseKernel::new(KernelBackend::Scalar);
        let mut simd = DenseKernel::new(KernelBackend::Simd);
        let a = scalar.truncated_column(&view, 0, &hist, &params);
        let b = simd.truncated_column(&view, 0, &hist, &params);
        assert_eq!(a.ids, b.ids, "kept ids (params {params:?})");
        for (i, (x, y)) in a.weights.iter().zip(&b.weights).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "weight[{i}] (params {params:?})");
        }
        assert_eq!(a.sum.to_bits(), b.sum.to_bits(), "sum (params {params:?})");
        let u = rng.next_f64();
        assert_eq!(
            simd.decide(&view, 0, &hist, &params, u),
            scalar.decide(&view, 0, &hist, &params, u),
            "token at u={u} (params {params:?})"
        );
    });
}

#[test]
fn prop_shvs_matches_oracle_distribution() {
    // The heavyweight exactness property: SHVS empirical distribution over
    // many uniforms matches the full-V oracle within Monte-Carlo noise.
    props("shvs exact", 6, |rng| {
        let vocab = 40 + rng.next_below(80) as usize;
        let h = 8 + rng.next_below(vocab as u64 / 3) as usize;
        let logits = random_logits(rng, vocab);
        let view = shard_row_major(
            &Tensor2::from_vec(1, vocab, logits.clone()),
            1 + rng.next_below(3) as usize,
        );
        let mut ids: Vec<u32> = (0..vocab as u32).collect();
        rng.shuffle(&mut ids);
        ids.truncate(h);
        let hot = HotVocab::new(ids, vocab).into_arc();
        let params = random_params(rng, vocab);
        let mut hist = SeqHistory::new(&[3]);
        hist.append(5 % vocab as u32);

        let pre = Precompute::reference(&view, 0, &hot, params.temperature.max(1e-6));
        let mut sampler = ShvsSampler::new(hot);
        let n = 60_000;
        let mut counts = vec![0.0f64; vocab];
        for _ in 0..n {
            let u = (rng.next_f64(), rng.next_f64(), rng.next_f64());
            let d = sampler.decide(&view, 0, &hist, &params, &pre, u);
            counts[d.token as usize] += 1.0;
        }
        // oracle
        let mut row = logits;
        apply_penalties_dense(&mut row, &hist, &params);
        let pairs: Vec<(u32, f32)> =
            row.iter().enumerate().map(|(i, &z)| (i as u32, z)).collect();
        let t = filter::truncate(pairs, &params);
        let oracle = dist_of(&t, vocab);
        let tvd = total_variation_distance(&counts, &oracle);
        assert!(tvd < 0.02, "tvd {tvd} (params {params:?})");
    });
}

/// Drive a full SamplerService decode with speculative windows of size `k`
/// over `m` samplers, on the context-SENSITIVE synthetic data plane
/// (logits keyed by (seq, decode_iter, fed token) — a bug committing past
/// the accept point changes the logits it sees and breaks the stream).
/// Returns each sequence's first `total` committed tokens.
fn spec_service_streams(
    vocab: usize,
    params_base: &SamplingParams,
    m: usize,
    k: usize,
    total: usize,
    gen_seed: u64,
) -> Vec<Vec<u32>> {
    let b = 3usize;
    let gen = LogitsGen::new(vocab, 1.1, gen_seed);
    let proposer = DraftProposer::new();
    let cfg = SamplerConfig {
        num_samplers: m,
        variant: DecisionVariant::Offloading,
        seed: 0xA11CE,
        ..Default::default()
    };
    let svc = SamplerService::start(&cfg, None, 4 * total + 32);
    let prompts: Vec<Vec<u32>> =
        (0..b).map(|s| vec![(s % vocab) as u32, 1]).collect();
    let params: Vec<SamplingParams> = (0..b)
        .map(|s| SamplingParams { seed: params_base.seed ^ ((s as u64) << 3), ..params_base.clone() })
        .collect();
    let handles: Vec<SeqHandle> = (0..b)
        .map(|s| svc.register(s as u64, &prompts[s], &params[s]))
        .collect();
    let mut streams: Vec<Vec<u32>> = vec![Vec::new(); b];
    let mut iter = 0u64;
    while streams.iter().any(|s| s.len() < total) {
        let live: Vec<usize> = (0..b).filter(|&s| streams[s].len() < total).collect();
        let drafts: Vec<Vec<u32>> = live
            .iter()
            .map(|&s| proposer.propose(params[s].seed, vocab, &prompts[s], &streams[s], k))
            .collect();
        let columns: Vec<ColumnMeta> = live
            .iter()
            .enumerate()
            .map(|(col, &s)| ColumnMeta {
                col,
                seq_id: s as u64,
                iteration: streams[s].len() as u64,
            })
            .collect();
        let col_keys: Vec<(u64, u64, u32)> = live
            .iter()
            .map(|&s| {
                let fed0 = streams[s].last().copied().unwrap_or(prompts[s][1]);
                (s as u64, streams[s].len() as u64, fed0)
            })
            .collect();
        let views = chain_views(&gen, &col_keys, &drafts, 2);
        let recs: Vec<Option<SeqHandle>> =
            live.iter().map(|&s| Some(handles[s].clone())).collect();
        svc.submit(IterationTask {
            iter,
            mb: 0,
            views,
            columns: Arc::new(columns),
            recs: Arc::new(recs),
            pre: Arc::new(Vec::new()),
            drafts: Arc::new(drafts),
        });
        let (decisions, _busy) = svc.collect(iter, live.len());
        assert_eq!(decisions.len(), live.len());
        for (_, seq, verdict) in decisions {
            assert!(verdict.tokens.len() == verdict.accepted + 1);
            streams[seq as usize].extend(&verdict.tokens);
        }
        iter += 1;
    }
    for h in &handles {
        svc.retire(h);
    }
    svc.shutdown();
    for s in streams.iter_mut() {
        s.truncate(total);
    }
    streams
}

#[test]
fn prop_spec_decode_streams_bit_identical_for_any_k_and_m() {
    // The tentpole differential property: verified speculative decode is
    // invisible in the tokens — for random sampler params (penalties,
    // truncation combos), any window size k, and any sampler count m, the
    // committed streams equal non-speculative single-sampler decode.
    props("spec streams == plain", 8, |rng| {
        let vocab = 64 + rng.next_below(200) as usize;
        let mut params = random_params(rng, vocab);
        params.seed = rng.next_u64();
        let gen_seed = rng.next_u64();
        let total = 12 + rng.next_below(10) as usize;
        let baseline = spec_service_streams(vocab, &params, 1, 0, total, gen_seed);
        let k = 1 + rng.next_below(4) as usize;
        let m = 1 + rng.next_below(4) as usize;
        let spec = spec_service_streams(vocab, &params, m, k, total, gen_seed);
        assert_eq!(spec, baseline, "k={k} m={m} params={params:?}");
    });
}

/// Run the real pipelined executor end to end over the context-faithful
/// synthetic data plane, returning each finished request's token stream.
fn synthetic_engine_streams(
    reqs: &[(Vec<u32>, usize, SamplingParams)],
    vocab: usize,
    plane_seed: u64,
    n_mb: usize,
    overlap: bool,
    m: usize,
    spec_k: usize,
) -> Vec<(u64, Vec<u32>)> {
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = m;
    cfg.sampler.seed = 0xF1E1D;
    cfg.n_microbatches = n_mb;
    cfg.overlap = overlap;
    cfg.spec_k = spec_k;
    cfg.idle_poll_us = 0;
    let runtime = SyntheticRuntime::new(4, vocab, 96, plane_seed);
    let mut engine = Engine::new(runtime, &cfg, None);
    for (i, (prompt, max_new, params)) in reqs.iter().enumerate() {
        let mut r = Request::new(i as u64, prompt.clone(), *max_new);
        r.params = params.clone();
        engine.submit(r);
    }
    engine.run_until_idle().expect("synthetic engine run");
    let mut fin: Vec<(u64, Vec<u32>)> = engine
        .take_finished()
        .into_iter()
        .map(|f| (f.request.id, f.output))
        .collect();
    engine.shutdown();
    fin.sort();
    fin
}

#[test]
fn prop_overlapped_executor_streams_equal_synchronous() {
    // The tentpole differential property: the pipelined executor with
    // in-flight microbatches and an asynchronous two-phase-commit decision
    // plane commits bit-identical streams to the synchronous single-
    // microbatch engine, for random sampler params × n_microbatches ×
    // sampler count m × speculative window k. Overlap changes timing,
    // never tokens.
    props("overlapped streams == sync", 6, |rng| {
        let vocab = 64 + rng.next_below(192) as usize;
        let n_req = 3 + rng.next_below(4) as usize;
        let reqs: Vec<(Vec<u32>, usize, SamplingParams)> = (0..n_req)
            .map(|i| {
                let plen = 1 + rng.next_below(6) as usize;
                let prompt: Vec<u32> =
                    (0..plen).map(|_| rng.next_below(vocab as u64) as u32).collect();
                let max_new = 3 + rng.next_below(10) as usize;
                let mut params = random_params(rng, vocab);
                params.seed = rng.next_u64() ^ ((i as u64) << 5);
                (prompt, max_new, params)
            })
            .collect();
        let plane_seed = rng.next_u64();
        let baseline = synthetic_engine_streams(&reqs, vocab, plane_seed, 1, false, 1, 0);
        assert_eq!(baseline.len(), n_req, "all requests finish");
        let n_mb = [2usize, 3, 4][rng.next_below(3) as usize];
        let m = 1 + rng.next_below(4) as usize;
        let spec_k = rng.next_below(4) as usize;
        let overlapped =
            synthetic_engine_streams(&reqs, vocab, plane_seed, n_mb, true, m, spec_k);
        assert_eq!(overlapped, baseline, "n_mb={n_mb} m={m} spec_k={spec_k}");
        // microbatching without async overlap must also be invisible
        let pipelined_sync =
            synthetic_engine_streams(&reqs, vocab, plane_seed, n_mb, false, m, spec_k);
        assert_eq!(pipelined_sync, baseline, "sync n_mb={n_mb} m={m} spec_k={spec_k}");
    });
}

/// Run the same requests through a routed cluster of synthetic-plane
/// replicas (same plane seed + sampler seed as [`synthetic_engine_streams`],
/// so the single engine is the ground truth). `engine_faults` carries the
/// engine-level chaos schedule (sampler kills, legacy poisons); router-level
/// replica kills ride in `ccfg.faults`.
fn routed_streams(
    reqs: &[(Vec<u32>, usize, SamplingParams)],
    vocab: usize,
    plane_seed: u64,
    ccfg: &ClusterConfig,
    m: usize,
    n_mb: usize,
    spec_k: usize,
    engine_faults: FaultPlan,
) -> Vec<(u64, Vec<u32>)> {
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = m;
    cfg.sampler.seed = 0xF1E1D;
    cfg.n_microbatches = n_mb;
    cfg.overlap = n_mb > 1;
    cfg.spec_k = spec_k;
    cfg.idle_poll_us = 10;
    cfg.faults = engine_faults;
    let mut cluster = Cluster::start(&cfg, ccfg, None, 96, move |_id| {
        Ok(SyntheticRuntime::new(4, vocab, 96, plane_seed))
    });
    let requests: Vec<Request> = reqs
        .iter()
        .enumerate()
        .map(|(i, (prompt, max_new, params))| {
            let mut r = Request::new(i as u64, prompt.clone(), *max_new);
            r.params = params.clone();
            r
        })
        .collect();
    cluster.run(requests).expect("cluster run");
    let report = cluster.shutdown().expect("cluster shutdown");
    let mut fin: Vec<(u64, Vec<u32>)> = report
        .finished
        .iter()
        .map(|s| (s.request.id, s.output.clone()))
        .collect();
    fin.sort();
    fin
}

#[test]
fn prop_routed_streams_equal_single_replica() {
    // The cluster-layer differential property: for random routing policy ×
    // replica count × sampler count × speculative window × microbatch
    // count (± a shared sampler pool, ± a prefill/decode split), routed
    // per-sequence streams are bit-identical to one engine serving the
    // whole trace. Routing moves work, never decisions.
    props("routed streams == single replica", 5, |rng| {
        let vocab = 64 + rng.next_below(192) as usize;
        let n_req = 4 + rng.next_below(5) as usize;
        let reqs: Vec<(Vec<u32>, usize, SamplingParams)> = (0..n_req)
            .map(|i| {
                let plen = 1 + rng.next_below(6) as usize;
                let prompt: Vec<u32> =
                    (0..plen).map(|_| rng.next_below(vocab as u64) as u32).collect();
                let max_new = 2 + rng.next_below(10) as usize;
                let mut params = random_params(rng, vocab);
                params.seed = rng.next_u64() ^ ((i as u64) << 5);
                (prompt, max_new, params)
            })
            .collect();
        let plane_seed = rng.next_u64();
        let baseline = synthetic_engine_streams(&reqs, vocab, plane_seed, 1, false, 1, 0);
        assert_eq!(baseline.len(), n_req, "all requests finish");
        let policy = RoutePolicy::ALL[rng.next_below(RoutePolicy::ALL.len() as u64) as usize];
        let replicas = 1 + rng.next_below(4) as usize;
        let m = 1 + rng.next_below(3) as usize;
        let spec_k = rng.next_below(3) as usize;
        let n_mb = 1 + rng.next_below(2) as usize;
        let mut ccfg = ClusterConfig::default();
        ccfg.replicas = replicas;
        ccfg.policy = policy;
        ccfg.shared_samplers = rng.next_f64() < 0.5;
        let routed = routed_streams(
            &reqs, vocab, plane_seed, &ccfg, m, n_mb, spec_k, FaultPlan::default(),
        );
        assert_eq!(
            routed, baseline,
            "policy={} replicas={replicas} shared={} m={m} spec_k={spec_k} n_mb={n_mb}",
            policy.name(),
            ccfg.shared_samplers
        );
        if replicas >= 2 {
            // the DistServe-style split (handoff + transfer delay) must be
            // just as invisible in the tokens
            ccfg.prefill_replicas = 1;
            let split = routed_streams(
                &reqs, vocab, plane_seed, &ccfg, m, n_mb, spec_k, FaultPlan::default(),
            );
            assert_eq!(
                split, baseline,
                "split fleet: policy={} replicas={replicas} m={m} spec_k={spec_k}",
                policy.name()
            );
        }
    });
}

#[test]
fn prop_streams_identical_under_injected_faults() {
    // The hardening hard bar (DESIGN.md §10): for RANDOM fault plans —
    // sampler kills, legacy poisons, replica kills, in any combination —
    // across random (replicas × m × spec_k × n_microbatches ± shared
    // pool), recovery replays state deterministically: per-sequence token
    // streams are bit-identical to the fault-free single-engine run, and
    // every request still finishes.
    props("streams identical under injected faults", 4, |rng| {
        let vocab = 64 + rng.next_below(192) as usize;
        let n_req = 4 + rng.next_below(4) as usize;
        let reqs: Vec<(Vec<u32>, usize, SamplingParams)> = (0..n_req)
            .map(|i| {
                let plen = 1 + rng.next_below(6) as usize;
                let prompt: Vec<u32> =
                    (0..plen).map(|_| rng.next_below(vocab as u64) as u32).collect();
                let max_new = 3 + rng.next_below(10) as usize;
                let mut params = random_params(rng, vocab);
                params.seed = rng.next_u64() ^ ((i as u64) << 5);
                (prompt, max_new, params)
            })
            .collect();
        let plane_seed = rng.next_u64();
        let baseline = synthetic_engine_streams(&reqs, vocab, plane_seed, 1, false, 1, 0);
        assert_eq!(baseline.len(), n_req, "all requests finish fault-free");
        let replicas = 1 + rng.next_below(3) as usize;
        let m = 1 + rng.next_below(3) as usize;
        let spec_k = rng.next_below(3) as usize;
        let n_mb = 1 + rng.next_below(2) as usize;
        // random fault plan: 1-2 sampler kills, maybe a legacy poison
        // (now a clean kill of worker 0 under the lock-free service), and
        // (with a survivor available) maybe a replica kill
        let mut engine_faults = FaultPlan::default();
        for _ in 0..(1 + rng.next_below(2)) {
            engine_faults.push(
                rng.next_below(15),
                FaultKind::KillSampler { sampler: rng.next_below(m as u64) as usize },
            );
        }
        if rng.next_f64() < 0.4 {
            engine_faults.push(rng.next_below(10), FaultKind::PoisonLock);
        }
        let mut ccfg = ClusterConfig::default();
        ccfg.replicas = replicas;
        ccfg.policy = RoutePolicy::ALL[rng.next_below(RoutePolicy::ALL.len() as u64) as usize];
        if replicas >= 2 && rng.next_f64() < 0.6 {
            ccfg.faults.push(
                1 + rng.next_below(n_req as u64),
                FaultKind::KillReplica {
                    replica: rng.next_below(replicas as u64) as usize,
                },
            );
        }
        let plan_desc =
            format!("engine[{}] router[{}]", engine_faults.render(), ccfg.faults.render());
        // Sweep BOTH pool modes under the same fault plan: per-replica
        // pools, and the lock-free shared pool (where kills land on pool
        // workers serving every replica, recovery resubmits through the
        // shared slot table, and a `poison@` event must be a clean worker
        // kill rather than a poisoned-mutex cascade).
        for shared in [false, true] {
            ccfg.shared_samplers = shared;
            let routed = routed_streams(
                &reqs,
                vocab,
                plane_seed,
                &ccfg,
                m,
                n_mb,
                spec_k,
                engine_faults.clone(),
            );
            assert_eq!(
                routed, baseline,
                "chaos {plan_desc}: policy={} replicas={replicas} shared={shared} \
                 m={m} spec_k={spec_k} n_mb={n_mb}",
                ccfg.policy.name(),
            );
        }
    });
}

#[test]
fn prop_verify_rollback_leaves_history_equal_to_commits() {
    // Random (even adversarial garbage) drafts: after every window the
    // owner history holds exactly the committed tokens — rejected
    // roll-forward must leave zero residue in counts or rows.
    props("verify rollback residue-free", 30, |rng| {
        let vocab = 48 + rng.next_below(150) as usize;
        let gen = LogitsGen::new(vocab, 1.1, rng.next_u64());
        let mut params = random_params(rng, vocab);
        params.seed = rng.next_u64();
        let mut pipe = DecisionPipeline::new(DecisionVariant::Offloading, None, 3);
        let prompt = vec![rng.next_below(vocab as u64) as u32];
        let mut hist = BatchHistory::new(&[prompt.clone()], 256);
        let mut grammar: GrammarSlot = None;
        let mut out: Vec<u32> = Vec::new();
        for _ in 0..6 {
            let k = rng.next_below(5) as usize;
            let draft: Vec<u32> =
                (0..k).map(|_| rng.next_below(vocab as u64) as u32).collect();
            let base = out.len() as u64;
            let fed0 = out.last().copied().unwrap_or(prompt[0]);
            let views = chain_views(
                &gen,
                &[(9, base, fed0)],
                std::slice::from_ref(&draft),
                1,
            );
            let v = verify_window(
                &mut pipe, &views, 0, &draft, &mut hist, &mut grammar, &params, &[],
                9, base,
            );
            assert_eq!(v.tokens[..v.accepted], draft[..v.accepted]);
            out.extend(&v.tokens);
            assert_eq!(hist.column(0), out);
            assert_eq!(hist.seq(0).out_len(), out.len());
            // incremental counts equal a from-scratch rebuild
            for (&t, &c) in &hist.rebuild(0) {
                assert_eq!(hist.seq(0).out_count(t), c);
            }
        }
    });
}

#[test]
fn prop_kv_allocator_conserves_blocks() {
    props("kv conservation", 80, |rng| {
        let blocks = 4 + rng.next_below(60) as usize;
        let mut alloc = KvAllocator::new(blocks, 1 + rng.next_below(32) as usize);
        let mut live: Vec<u64> = Vec::new();
        for op in 0..200u64 {
            match rng.next_below(3) {
                0 => {
                    let tokens = 1 + rng.next_below(64) as usize;
                    if alloc.can_admit(tokens) {
                        let id = op * 1000;
                        alloc.admit(id, tokens).unwrap();
                        live.push(id);
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let i = rng.next_below(live.len() as u64) as usize;
                        let id = live.swap_remove(i);
                        alloc.release(id).unwrap();
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let i = rng.next_below(live.len() as u64) as usize;
                        let _ = alloc.grow(live[i], 1 + rng.next_below(96) as usize);
                    }
                }
            }
            alloc.check_invariants().unwrap();
        }
        for id in live {
            alloc.release(id).unwrap();
        }
        assert_eq!(alloc.free_blocks(), blocks);
    });
}

#[test]
fn prop_kv_prefix_sharing_interleavings_hold_invariants() {
    // Random admit_shared / grow / publish / release / evict / clear_index
    // interleavings over a pool of shared stems, so radix hits, COW forks,
    // LRU eviction, and refcounted sharing all fire mid-sweep. After every
    // op the allocator must account for each block exactly once (no leaks,
    // no double-frees, no aliasing), and draining everything must return
    // the pool to fully free.
    props("kv prefix interleavings", 60, |rng| {
        let bt = 1 + rng.next_below(8) as usize;
        let blocks = 8 + rng.next_below(56) as usize;
        let mut alloc = KvAllocator::new(blocks, bt);
        let stems: Vec<Vec<u32>> = (0..3u32)
            .map(|s| {
                let len = bt * (1 + rng.next_below(4) as usize);
                (0..len as u32).map(|i| i * 31 + s * 1000 + 7).collect()
            })
            .collect();
        // (seq id, known context, admitted capacity)
        let mut live: Vec<(u64, Vec<u32>, usize)> = Vec::new();
        for op in 0..250u64 {
            match rng.next_below(6) {
                0 | 1 => {
                    let stem = &stems[rng.next_below(stems.len() as u64) as usize];
                    let tail = 1 + rng.next_below(2 * bt as u64 + 1) as usize;
                    let mut ctx = stem.clone();
                    ctx.extend((0..tail as u32).map(|i| op as u32 * 131 + i));
                    let total = ctx.len() + rng.next_below(bt as u64 + 1) as usize;
                    let probe = alloc.probe(&ctx, total);
                    match alloc.admit_shared(op, &ctx, total) {
                        Ok(out) => {
                            // probe is read-only and ran just before, so
                            // the walk (and thus the hit) must agree.
                            assert_eq!(out.cached_tokens, probe.cached_tokens);
                            assert!(out.cached_tokens < ctx.len());
                            live.push((op, ctx, total));
                        }
                        Err(simple_serve::engine::kvcache::KvError::OutOfBlocks { .. }) => {
                            // probe.fits is conservative (it never counts
                            // the matched path as evictable), so a promised
                            // fit must never be refused.
                            assert!(!probe.fits, "probe promised a fit, admit refused");
                        }
                        Err(e) => panic!("unexpected admit error: {e}"),
                    }
                }
                2 => {
                    if !live.is_empty() {
                        let i = rng.next_below(live.len() as u64) as usize;
                        let extra = 1 + rng.next_below(3 * bt as u64) as usize;
                        if alloc.grow(live[i].0, live[i].2 + extra).is_ok() {
                            live[i].2 += extra;
                        }
                    }
                }
                3 => {
                    if !live.is_empty() {
                        let i = rng.next_below(live.len() as u64) as usize;
                        let (id, ref ctx, _) = live[i];
                        let upto = rng.next_below(ctx.len() as u64 + 1) as usize;
                        alloc.publish(id, &ctx[..upto]).unwrap();
                    }
                }
                4 => {
                    if !live.is_empty() {
                        let i = rng.next_below(live.len() as u64) as usize;
                        let (id, _, _) = live.swap_remove(i);
                        alloc.release(id).unwrap();
                    }
                }
                _ => {
                    if rng.next_f64() < 0.2 {
                        alloc.clear_index();
                    } else {
                        alloc.evict(1 + rng.next_below(4) as usize);
                    }
                }
            }
            if let Err(e) = alloc.check_invariants() {
                panic!("invariants broken after op {op}: {e}");
            }
        }
        for (id, _, _) in live {
            alloc.release(id).unwrap();
        }
        alloc.clear_index();
        alloc.check_invariants().unwrap();
        assert_eq!(alloc.free_blocks(), blocks, "drained pool must be fully free");
    });
}

#[test]
fn prop_sizing_h_star_is_argmin() {
    props("sizing argmin", 25, |rng| {
        let vocab = 2_000 + rng.next_below(50_000) as usize;
        let s = 0.9 + rng.next_f64() * 0.5;
        let knots = simple_serve::decision::sizing::zipf_alpha_knots(vocab, s, 16);
        let c = 1e-9 + rng.next_f64() * 1e-7;
        let c0 = 1e-6 + rng.next_f64() * 1e-5;
        let cost: Vec<(f64, f64)> = knots
            .iter()
            .map(|&(h, _)| (h, c * h + c0))
            .collect();
        let model = simple_serve::decision::sizing::SizingModel::fit(&cost, &knots, vocab);
        let h_star = model.h_star();
        // brute force over a coarse grid
        let (lo, hi) = model.alpha.domain();
        let mut best = f64::INFINITY;
        let mut h = lo;
        while h <= hi {
            best = best.min(model.f(h));
            h += (hi - lo) / 2000.0;
        }
        let rel = (model.f(h_star as f64) - best) / best;
        assert!(rel < 0.02, "F(H*) {:.3e} vs brute {best:.3e}", model.f(h_star as f64));
    });
}

#[test]
fn prop_spsc_ring_fifo_under_random_interleaving() {
    props("spsc fifo", 40, |rng| {
        let cap = 2usize.pow(1 + rng.next_below(6) as u32);
        let (p, c) = simple_serve::ringbuf::spsc::ring::<u64>(cap);
        let mut next_push = 0u64;
        let mut next_pop = 0u64;
        for _ in 0..2000 {
            if rng.next_f64() < 0.55 {
                if p.try_push(next_push).is_ok() {
                    next_push += 1;
                }
            } else if let Ok(v) = c.try_pop() {
                assert_eq!(v, next_pop);
                next_pop += 1;
            }
        }
        while let Ok(v) = c.try_pop() {
            assert_eq!(v, next_pop);
            next_pop += 1;
        }
        assert_eq!(next_pop, next_push);
    });
}

#[test]
fn prop_zero_copy_view_equals_dense() {
    props("sharded view == dense", 60, |rng| {
        let b = 1 + rng.next_below(6) as usize;
        let v = 8 + rng.next_below(300) as usize;
        let shards = 1 + rng.next_below(5.min(v as u64)) as usize;
        let data = random_logits(rng, b * v);
        let t = Tensor2::from_vec(b, v, data);
        let view = shard_row_major(&t, shards);
        for bi in 0..b {
            assert_eq!(view.materialize_row(bi), t.row(bi));
        }
    });
}
