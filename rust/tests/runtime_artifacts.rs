//! Integration: AOT artifacts → PJRT runtime → engine end-to-end.
//!
//! These tests need `make artifacts` (they skip, loudly, if missing).

// Config structs are built by `default()` + field assignment (sweep-driver
// idiom); see the identical crate-level allow in lib.rs.
#![allow(clippy::field_reassign_with_default)]

use simple_serve::config::{DecisionVariant, EngineConfig};
use simple_serve::decision::HotVocab;
use simple_serve::engine::{PjrtEngine, Request};
use simple_serve::runtime::{default_artifacts_dir, Manifest, ModelRuntime};
use simple_serve::workload;

fn manifest() -> Option<Manifest> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        return None;
    }
    Some(Manifest::load(&dir).expect("manifest parses"))
}

#[test]
fn runtime_loads_and_steps() {
    let Some(m) = manifest() else { return };
    let mut rt = ModelRuntime::load(&m, "micro-test").unwrap();
    let b = rt.batch();
    let v = rt.vocab();
    let ids = vec![5i32; b];
    let pos = vec![0i32; b];
    let tau = vec![1.0f32; b];
    let out = rt.step(&ids, &pos, &tau).unwrap();
    assert_eq!(out.logits.len(), b * v);
    assert_eq!(out.stats.len(), b);
    assert!(out.logits.iter().all(|z| z.is_finite()));
    for s in &out.stats {
        // z_max, sums finite; with an all-cold hot mask, s_hot == 0
        assert!(s.iter().all(|x| x.is_finite()));
        assert_eq!(s[1], 0.0, "no hot mask installed yet");
        assert!(s[2] > 0.0);
    }
}

#[test]
fn runtime_stats_match_logits() {
    // The kernel's stats must agree with recomputing from the logits.
    let Some(m) = manifest() else { return };
    let mut rt = ModelRuntime::load(&m, "micro-test").unwrap();
    let v = rt.vocab();
    let b = rt.batch();
    let hot = HotVocab::new((0..64u32).collect(), v);
    rt.set_hot_vocab(&hot);
    let out = rt
        .step(&vec![3i32; b], &vec![0i32; b], &vec![0.8f32; b])
        .unwrap();
    for (bi, s) in out.stats.iter().enumerate() {
        let row = &out.logits[bi * v..(bi + 1) * v];
        let z_max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!((s[0] - z_max).abs() < 1e-4, "z_max {} vs {}", s[0], z_max);
        let (mut s_hot, mut s_tail, mut t_max) = (0.0f64, 0.0f64, 0.0f64);
        for (i, &z) in row.iter().enumerate() {
            let w = (((z - z_max) / 0.8) as f64).exp();
            if hot.contains(i as u32) {
                s_hot += w;
            } else {
                s_tail += w;
                t_max = t_max.max(w);
            }
        }
        assert!((s[1] as f64 - s_hot).abs() / s_hot.max(1e-9) < 2e-3, "s_hot");
        assert!((s[2] as f64 - s_tail).abs() / s_tail.max(1e-9) < 2e-3, "s_tail");
        assert!((s[3] as f64 - t_max).abs() / t_max.max(1e-9) < 2e-3, "t_max");
    }
}

#[test]
fn kv_cache_carries_state() {
    // Feeding the same token at position 1 after different position-0
    // tokens must give different logits (the cache matters).
    let Some(m) = manifest() else { return };
    let mut rt = ModelRuntime::load(&m, "micro-test").unwrap();
    let b = rt.batch();
    let run = |rt: &mut ModelRuntime, first: i32| -> Vec<f32> {
        rt.reset_kv();
        rt.step(&vec![first; b], &vec![0i32; b], &vec![1.0f32; b]).unwrap();
        rt.step(&vec![7i32; b], &vec![1i32; b], &vec![1.0f32; b])
            .unwrap()
            .logits
    };
    let a = run(&mut rt, 3);
    let c = run(&mut rt, 200);
    let diff: f32 = a.iter().zip(&c).map(|(x, y)| (x - y).abs()).sum();
    assert!(diff > 1e-3, "KV cache has no effect? diff {diff}");
    // and determinism: same history -> same logits
    let a2 = run(&mut rt, 3);
    assert_eq!(a, a2);
}

#[test]
fn reset_kv_slot_isolates_sequences() {
    let Some(m) = manifest() else { return };
    let mut rt = ModelRuntime::load(&m, "micro-test").unwrap();
    let b = rt.batch();
    // Build state, then reset slot 0 only; slot 0 then diverges from slot 1
    // even though both receive identical inputs.
    rt.step(&vec![9i32; b], &vec![0i32; b], &vec![1.0f32; b]).unwrap();
    rt.reset_kv_slot(0);
    let out = rt.step(&vec![4i32; b], &vec![1i32; b], &vec![1.0f32; b]).unwrap();
    let v = rt.vocab();
    let slot0 = &out.logits[0..v];
    let slot1 = &out.logits[v..2 * v];
    let diff: f32 = slot0.iter().zip(slot1).map(|(x, y)| (x - y).abs()).sum();
    assert!(diff > 1e-3, "slot reset should desync identical slots");
}

#[test]
fn engine_serves_trace_end_to_end_shvs() {
    let Some(m) = manifest() else { return };
    let rt = ModelRuntime::load(&m, "micro-test").unwrap();
    let vocab = rt.vocab();
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Shvs;
    cfg.sampler.num_samplers = 2;
    let hot = HotVocab::from_synthetic_trace(vocab, 100, 1.1, 20_000, 1).into_arc();
    let mut engine = PjrtEngine::new(rt, &cfg, Some(hot));

    let trace = workload::generate(&workload::TraceConfig::tiny(12, vocab));
    let total_expected: usize = trace.output_lens.iter().sum();
    for r in trace.requests {
        engine.submit(r);
    }
    let summary = engine.run_until_idle().unwrap();
    assert_eq!(summary.finished, 12);
    assert_eq!(summary.tokens, total_expected);
    assert!(summary.throughput > 0.0);
    let finished = engine.take_finished();
    assert_eq!(finished.len(), 12);
    for f in &finished {
        assert!(f.output.iter().all(|&t| (t as usize) < vocab));
        assert_eq!(f.output.len(), f.request.max_new_tokens);
    }
    let (_, stats) = engine.shutdown();
    let decisions: u64 = stats.iter().map(|s| s.decisions).sum();
    assert_eq!(decisions as usize, total_expected);
}

#[test]
fn engine_variants_produce_same_token_count() {
    let Some(m) = manifest() else { return };
    let vocab = m.model("micro-test").unwrap().vocab;
    let hot = HotVocab::from_synthetic_trace(vocab, 100, 1.1, 20_000, 1).into_arc();
    let mut results = Vec::new();
    for variant in [
        DecisionVariant::GpuEpilogue,
        DecisionVariant::Offloading,
        DecisionVariant::Shvs,
    ] {
        let rt = ModelRuntime::load(&m, "micro-test").unwrap();
        let mut cfg = EngineConfig::default();
        cfg.sampler.variant = variant;
        cfg.sampler.num_samplers = 2;
        let mut engine = PjrtEngine::new(rt, &cfg, Some(hot.clone()));
        let trace = workload::generate(&workload::TraceConfig::tiny(6, vocab));
        for r in trace.requests {
            engine.submit(r);
        }
        let summary = engine.run_until_idle().unwrap();
        results.push((variant, summary.tokens, summary.finished));
    }
    let tokens0 = results[0].1;
    for (v, tokens, finished) in &results {
        assert_eq!(*finished, 6, "{v:?}");
        assert_eq!(*tokens, tokens0, "{v:?} token count");
    }
}

#[test]
fn engine_open_loop_arrivals() {
    let Some(m) = manifest() else { return };
    let rt = ModelRuntime::load(&m, "micro-test").unwrap();
    let vocab = rt.vocab();
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = 1;
    let mut engine = PjrtEngine::new(rt, &cfg, None);
    let mut trace = workload::generate(&workload::TraceConfig::tiny(8, vocab));
    workload::poisson_arrivals(&mut trace, 200.0, 9);
    for r in trace.requests {
        engine.submit(r);
    }
    let summary = engine.run_until_idle().unwrap();
    assert_eq!(summary.finished, 8);
    // TTFT must include queueing: every request has a first token
    assert_eq!(summary.ttft.n, 8);
}

#[test]
fn engine_preempts_under_kv_pressure_and_serves_exactly() {
    let Some(m) = manifest() else { return };
    let rt = ModelRuntime::load(&m, "micro-test").unwrap();
    let vocab = rt.vocab();
    let max_seq = rt.max_seq();
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = 2;
    // Over-commit the cache to its floor (one max-length sequence + one
    // block) instead of the never-preempt b × max_seq sizing.
    cfg.kv_blocks = 1;
    let mut engine = PjrtEngine::new(rt, &cfg, None);
    // Every request grows from a 1-block prompt to nearly max_seq, so any
    // two concurrently-decoding sequences outgrow the floor-sized pool
    // (one max-length sequence + one block) whatever the model's batch is.
    let n = 6u64;
    let max_new = max_seq - 8;
    let mut expected = 0usize;
    for id in 0..n {
        let prompt: Vec<u32> = (0..4).map(|i| (id as u32 * 7 + i) % vocab as u32).collect();
        engine.submit(Request::new(id, prompt, max_new));
        expected += max_new;
    }
    let summary = engine.run_until_idle().unwrap();
    assert_eq!(summary.finished, n as usize);
    assert_eq!(summary.tokens, expected, "recompute-on-resume loses no tokens");
    assert!(
        engine.preemption_count() > 0,
        "over-committed cache must preempt (kv floor, {n} growing seqs)"
    );
    let finished = engine.take_finished();
    for f in &finished {
        assert_eq!(f.output.len(), max_new);
        assert!(f.output.iter().all(|&t| (t as usize) < vocab));
    }
    engine.shutdown();
}

#[test]
fn prompt_too_long_panics() {
    let Some(m) = manifest() else { return };
    let rt = ModelRuntime::load(&m, "micro-test").unwrap();
    let max_seq = rt.max_seq();
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    let mut engine = PjrtEngine::new(rt, &cfg, None);
    let huge = Request::new(0, vec![1; max_seq + 4], 4);
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        engine.submit(huge);
    }));
    assert!(res.is_err());
}
