//! Differential exactness suite for the lane-vectorized dense kernels
//! (decision/kernels.rs) and the adaptive-SHVS digest contract.
//!
//! The bit-identical-streams bar: the SIMD path must produce the same
//! `Truncated` sets (kept ids, per-id stable weights, f64 sums — compared
//! via `to_bits`) and the same sampled tokens as the scalar reference, for
//! every filter combination, on adversarial inputs: vocabularies straddling
//! the 8-wide lane boundary (8k±7, 32k±1), ±inf-adjacent magnitudes,
//! subnormals, signed zeros, and all-equal tie plateaus. Both backends are
//! constructed directly (`DenseKernel::new`), so the suite passes under
//! forced-scalar AND forced-SIMD dispatch regardless of `SIMPLE_KERNELS`;
//! one test additionally pins whatever `detect()` chose against the scalar
//! reference. The last tests pin the adaptive-sizing contract: SHVS token
//! digests are invariant under live hot-vocab resizes.

use simple_serve::decision::kernels::{DenseKernel, KernelBackend};
use simple_serve::decision::penalties::SeqHistory;
use simple_serve::decision::shvs::{Precompute, ShvsSampler};
use simple_serve::decision::SamplingParams;
use simple_serve::harness::measure::LogitsGen;
use simple_serve::rng::Philox;
use simple_serve::tensor::{shard_row_major, ShardedLogits, Tensor2};

fn view_of(logits: Vec<f32>, shards: usize) -> ShardedLogits {
    let v = logits.len();
    shard_row_major(&Tensor2::from_vec(1, v, logits), shards)
}

/// Logit generators, from smooth to adversarial.
fn flavored_logits(rng: &mut Philox, v: usize, flavor: usize) -> Vec<f32> {
    match flavor {
        // smooth Gaussian
        0 => (0..v).map(|_| rng.next_normal() as f32 * 2.0).collect(),
        // coarse quantization: dense ties at every level
        1 => (0..v).map(|_| (rng.next_f32() * 6.0).floor() * 0.5 - 1.5).collect(),
        // adversarial: ±inf-adjacent magnitudes, subnormals, signed zeros,
        // and a tie plateau
        2 => (0..v)
            .map(|i| match rng.next_below(8) {
                0 => f32::MAX,
                1 => -f32::MAX,
                2 => 1e-40,  // subnormal
                3 => -1e-40, // negative subnormal
                4 => 0.0,
                5 => -0.0,
                6 => 3.25, // plateau
                _ => (i % 17) as f32 * 0.25,
            })
            .collect(),
        // all-equal: every element ties
        _ => vec![1.0f32; v],
    }
}

/// The full filter-combination grid at vocabulary `v`: every top-k regime
/// (off, singleton, small, half, V−1, ≥V) × top-p on/off × min-p on/off ×
/// penalties+bias on/off.
fn param_grid(v: usize) -> Vec<SamplingParams> {
    let mut out = Vec::new();
    for &top_k in &[0usize, 1, 2, 7, v / 2, v - 1, v, v + 3] {
        for &top_p in &[1.0f32, 0.92] {
            for &min_p in &[0.0f32, 0.02] {
                for &pen in &[false, true] {
                    let mut p = SamplingParams {
                        temperature: 0.8,
                        top_k,
                        top_p,
                        min_p,
                        ..Default::default()
                    };
                    if pen {
                        p.repetition_penalty = 1.2;
                        p.presence_penalty = 0.1;
                        p.frequency_penalty = 0.05;
                        p.logit_bias.insert((v as u32) / 3, 0.75);
                    }
                    out.push(p);
                }
            }
        }
    }
    out
}

fn lived_in_history() -> SeqHistory {
    let mut hist = SeqHistory::new(&[5, 17, 17]);
    hist.append(100);
    hist.append(100);
    hist.append(3);
    hist
}

/// Assert the two backends' `Truncated` sets are bitwise identical and
/// their tokens agree across a uniform sweep.
fn assert_column_identical(
    scalar: &mut DenseKernel,
    simd: &mut DenseKernel,
    view: &ShardedLogits,
    hist: &SeqHistory,
    params: &SamplingParams,
    ctx: &str,
) {
    let a = scalar.truncated_column(view, 0, hist, params);
    let b = simd.truncated_column(view, 0, hist, params);
    assert_eq!(a.ids, b.ids, "{ctx}: kept ids diverge (params {params:?})");
    assert_eq!(a.weights.len(), b.weights.len(), "{ctx}");
    for (i, (x, y)) in a.weights.iter().zip(&b.weights).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: weight[{i}] {x} vs {y} (params {params:?})"
        );
    }
    assert_eq!(
        a.sum.to_bits(),
        b.sum.to_bits(),
        "{ctx}: sums {} vs {} (params {params:?})",
        a.sum,
        b.sum
    );
    assert_eq!(a.z_max.to_bits(), b.z_max.to_bits(), "{ctx}: z_max diverges");
    for i in 0..7 {
        let u = (i as f64 + 0.5) / 7.0;
        assert_eq!(
            simd.decide(view, 0, hist, params, u),
            scalar.decide(view, 0, hist, params, u),
            "{ctx}: token diverges at u={u} (params {params:?})"
        );
    }
}

#[test]
fn every_filter_combination_matches_scalar_bitwise() {
    let v = 769; // off every lane boundary
    let mut rng = Philox::new(41);
    let hist = lived_in_history();
    let mut scalar = DenseKernel::new(KernelBackend::Scalar);
    let mut simd = DenseKernel::new(KernelBackend::Simd);
    for flavor in 0..4 {
        let view = view_of(flavored_logits(&mut rng, v, flavor), 3);
        for params in param_grid(v) {
            assert_column_identical(
                &mut scalar,
                &mut simd,
                &view,
                &hist,
                &params,
                &format!("flavor={flavor}"),
            );
        }
    }
}

#[test]
fn off_boundary_vocabs_match_bitwise() {
    // V straddling the lane width at scale: 8k±7 and 32k±1.
    let mut rng = Philox::new(97);
    let hist = lived_in_history();
    let mut scalar = DenseKernel::new(KernelBackend::Scalar);
    let mut simd = DenseKernel::new(KernelBackend::Simd);
    for &v in &[8_192 - 7, 8_192 + 7, 32_768 - 1, 32_768 + 1] {
        for flavor in 0..4 {
            let view = view_of(flavored_logits(&mut rng, v, flavor), 1 + v % 3);
            let combos = [
                SamplingParams { temperature: 0.8, ..Default::default() },
                SamplingParams { temperature: 0.8, top_k: 1, ..Default::default() },
                SamplingParams::production_default(),
                SamplingParams {
                    temperature: 1.1,
                    top_k: v, // k ≥ V: must be a no-op on both backends
                    top_p: 0.9,
                    ..Default::default()
                },
                SamplingParams::greedy(),
            ];
            for params in combos {
                assert_column_identical(
                    &mut scalar,
                    &mut simd,
                    &view,
                    &hist,
                    &params,
                    &format!("v={v} flavor={flavor}"),
                );
            }
        }
    }
}

#[test]
fn greedy_and_allow_list_tokens_match() {
    let v = 1031;
    let mut rng = Philox::new(53);
    let hist = lived_in_history();
    let mut scalar = DenseKernel::new(KernelBackend::Scalar);
    let mut simd = DenseKernel::new(KernelBackend::Simd);
    for flavor in 0..4 {
        let view = view_of(flavored_logits(&mut rng, v, flavor), 2);
        // greedy: token = total-order argmax on both backends
        let greedy = SamplingParams::greedy();
        assert_eq!(
            simd.decide(&view, 0, &hist, &greedy, 0.5),
            scalar.decide(&view, 0, &hist, &greedy, 0.5),
            "flavor={flavor} greedy"
        );
        // allow-list (grammar-mask shape): SIMD delegates to the audited
        // scalar path — tokens must still agree for any mask
        let allow = SamplingParams {
            temperature: 0.8,
            allowed_tokens: Some(vec![3, 99, 512, 700, (v - 1) as u32]),
            ..Default::default()
        };
        for i in 0..5 {
            let u = (i as f64 + 0.5) / 5.0;
            assert_eq!(
                simd.decide(&view, 0, &hist, &allow, u),
                scalar.decide(&view, 0, &hist, &allow, u),
                "flavor={flavor} allow-list u={u}"
            );
        }
    }
}

#[test]
fn dispatched_backend_agrees_with_scalar() {
    // Whatever SIMPLE_KERNELS selects (the CI matrix runs both values),
    // the detected kernel must match the scalar reference bitwise.
    let backend = KernelBackend::detect();
    let v = 2053;
    let mut rng = Philox::new(71);
    let hist = lived_in_history();
    let mut detected = DenseKernel::new(backend);
    let mut scalar = DenseKernel::new(KernelBackend::Scalar);
    let view = view_of(flavored_logits(&mut rng, v, 1), 2);
    for params in param_grid(v).into_iter().step_by(5) {
        for i in 0..5 {
            let u = (i as f64 + 0.5) / 5.0;
            assert_eq!(
                detected.decide(&view, 0, &hist, &params, u),
                scalar.decide(&view, 0, &hist, &params, u),
                "backend={backend:?} u={u} params={params:?}"
            );
        }
    }
}

/// FNV-1a over the token stream.
fn fnv(mut h: u64, t: u32) -> u64 {
    h ^= t as u64;
    h.wrapping_mul(0x0000_0100_0000_01b3)
}
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

#[test]
fn adaptive_vs_static_shvs_digests_agree() {
    // The adaptive-sizing half of the bit-identical-streams contract: with
    // nested hot sets (one shared ranking) and the H-invariant coupled
    // walk, the SHVS token digest is the same for every static H — and for
    // a stream whose H is resized live mid-decode.
    let v = 1024;
    let gen = LogitsGen::new(v, 1.1, 33);
    let params = SamplingParams { temperature: 0.9, ..Default::default() };
    let hist = SeqHistory::new(&[]);
    let steps = 300u64;
    let uniforms = |it: u64| {
        let mut r = Philox::substream(99, it);
        (r.next_f64(), r.next_f64(), r.next_f64())
    };

    let digest_at = |h: usize| -> u64 {
        let hot = gen.ranked_hot_vocab(h).into_arc();
        let mut s = ShvsSampler::new(hot.clone());
        let mut d = FNV_OFFSET;
        for it in 0..steps {
            let view = gen.view(1, it, 1);
            let pre = Precompute::reference(&view, 0, &hot, params.temperature);
            let dec = s.decide(&view, 0, &hist, &params, &pre, uniforms(it));
            d = fnv(d, dec.token);
        }
        d
    };
    let reference = digest_at(64);
    for h in [16usize, 200, 512] {
        assert_eq!(digest_at(h), reference, "static H={h} digest diverged");
    }

    // Live resizes on a schedule — grow, shrink, grow past the start.
    let schedule: &[(u64, usize)] = &[(60, 96), (140, 48), (220, 300)];
    let mut hot = gen.ranked_hot_vocab(32).into_arc();
    let mut s = ShvsSampler::new(hot.clone());
    let mut d = FNV_OFFSET;
    for it in 0..steps {
        if let Some(&(_, h)) = schedule.iter().find(|&&(at, _)| at == it) {
            hot = hot.resize(h).into_arc();
            s.set_hot(hot.clone());
        }
        let view = gen.view(1, it, 1);
        let pre = Precompute::reference(&view, 0, &hot, params.temperature);
        let dec = s.decide(&view, 0, &hist, &params, &pre, uniforms(it));
        d = fnv(d, dec.token);
    }
    assert_eq!(d, reference, "adaptive resizing changed the stream digest");
}
