//! Cross-module integration tests that don't need the AOT artifacts
//! (those live in `runtime_artifacts.rs`): decision service under load,
//! simulator ↔ workload ↔ metrics composition, harness report plumbing.

use simple_serve::config::{DecisionVariant, SamplerConfig};
use simple_serve::decision::service::{ColumnMeta, IterationTask, SamplerService};
use simple_serve::decision::{SamplingParams, SeqHandle};
use simple_serve::harness::measure::LogitsGen;
use simple_serve::harness::{run_experiment, Effort, ALL_EXPERIMENTS};
use simple_serve::simulator::{simulate, DecisionMode, GpuModel, SimConfig};
use simple_serve::workload;
use std::collections::HashMap;

#[test]
fn service_sustains_many_iterations_with_churn() {
    // Sequences register/retire continuously while iterations stream —
    // the scheduler-facing contract under continuous batching.
    let vocab = 2_000;
    let gen = LogitsGen::new(vocab, 1.1, 9);
    let hot = gen.hot_vocab(200).into_arc();
    let cfg = SamplerConfig {
        num_samplers: 3,
        variant: DecisionVariant::Shvs,
        seed: 5,
        ..Default::default()
    };
    let svc = SamplerService::start(&cfg, Some(hot.clone()), 512);
    let params = SamplingParams::production_default();

    let batch = 6usize;
    let mut live: Vec<u64> = (0..batch as u64).collect();
    let mut handles: HashMap<u64, SeqHandle> = HashMap::new();
    for &s in &live {
        handles.insert(s, svc.register(s, &[1, 2], &params));
    }
    let mut next_id = batch as u64;
    let mut decided_total = 0usize;
    for iter in 0..60u64 {
        let view = gen.view(batch, iter, 2);
        let pre: Vec<_> = (0..batch)
            .map(|b| {
                simple_serve::decision::Precompute::reference(
                    &view,
                    b,
                    &hot,
                    params.temperature,
                )
            })
            .collect();
        let columns: Vec<ColumnMeta> = live
            .iter()
            .enumerate()
            .map(|(col, &seq_id)| ColumnMeta { col, seq_id, iteration: iter })
            .collect();
        let recs: Vec<Option<SeqHandle>> =
            live.iter().map(|s| handles.get(s).cloned()).collect();
        svc.submit(IterationTask::single(iter, view, columns, recs, pre));
        let (decisions, busy) = svc.collect(iter, live.len());
        assert_eq!(decisions.len(), live.len(), "iter {iter}");
        assert!(busy >= 0.0);
        decided_total += decisions.len();
        // churn: retire one sequence every 3 iters, admit a replacement
        if iter % 3 == 2 {
            let gone = live.remove((iter as usize) % live.len());
            if let Some(h) = handles.remove(&gone) {
                svc.retire(&h);
            }
            handles.insert(next_id, svc.register(next_id, &[4, 5, 6], &params));
            live.push(next_id);
            next_id += 1;
        }
    }
    for &s in &live {
        if let Some(h) = handles.remove(&s) {
            svc.retire(&h);
        }
    }
    let stats = svc.shutdown();
    let sum: u64 = stats.iter().map(|s| s.decisions).sum();
    assert_eq!(sum as usize, decided_total);
    assert_eq!(decided_total, 60 * batch);
}

#[test]
fn simulator_composes_with_workload_end_to_end() {
    let model = simple_serve::config::ModelSpec::llama31_70b();
    let platform = simple_serve::config::PlatformSpec::h100();
    let parallel = simple_serve::config::ParallelConfig::new(4, 2);
    let mut trace_w = workload::generate(&workload::TraceConfig::sharegpt_like(
        150,
        model.vocab,
        4096,
    ));
    workload::poisson_arrivals(&mut trace_w, 20.0, 3);
    let trace = simple_serve::simulator::serving::to_sim_requests(&trace_w);
    let expected: usize = trace.iter().map(|r| r.output_len).sum();

    let gpu = GpuModel::new(model, platform.clone(), parallel);
    let cfg = SimConfig::new(
        gpu,
        DecisionMode::SimpleOverlapped { per_seq_s: 50e-6, samplers: 16 },
        256,
        platform.cpu_cores,
        16,
    );
    let res = simulate(&cfg, &trace);
    assert_eq!(res.recorder.total_tokens(), expected);
    assert_eq!(res.recorder.finished_requests(), 150);
    assert!(res.throughput() > 100.0);
    // TTFT reflects queueing + prefill, TPOT is bounded by the cycle model
    assert!(res.recorder.ttft_summary().p50 > 0.0);
    assert!(res.recorder.tpot_summary().p99 < 1.0);
}

#[test]
fn every_experiment_runs_quick_and_writes_reports() {
    let dir = std::env::temp_dir().join(format!("simple_results_{}", std::process::id()));
    for id in ALL_EXPERIMENTS {
        let report = run_experiment(id, Effort::Quick)
            .unwrap_or_else(|e| panic!("{id}: {e:#}"));
        assert_eq!(&report.id, id);
        assert!(!report.markdown.is_empty());
        report.write(&dir).unwrap();
        assert!(dir.join(format!("{id}.md")).exists());
        assert!(dir.join(format!("{id}.json")).exists());
        // JSON parses back
        let parsed =
            simple_serve::util::json::read_json_file(&dir.join(format!("{id}.json")));
        assert!(parsed.is_ok(), "{id} json roundtrip");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn deterministic_service_streams_with_tp_sharded_views() {
    // Decisions must not depend on the TP shard count of the logits view.
    let vocab = 1_000;
    let gen = LogitsGen::new(vocab, 1.1, 11);
    let hot = gen.hot_vocab(128).into_arc();
    let params = SamplingParams::production_default();
    let mut streams: Vec<Vec<u32>> = Vec::new();
    for shards in [1usize, 4] {
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Shvs,
            seed: 21,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, Some(hot.clone()), 128);
        let handle = svc.register(0, &[7], &params);
        let mut out = Vec::new();
        for iter in 0..25u64 {
            let view = gen.view(1, iter, shards);
            let pre = vec![simple_serve::decision::Precompute::reference(
                &view,
                0,
                &hot,
                params.temperature,
            )];
            svc.submit(IterationTask::single(
                iter,
                view,
                vec![ColumnMeta { col: 0, seq_id: 0, iteration: iter }],
                vec![Some(handle.clone())],
                pre,
            ));
            let (d, _) = svc.collect(iter, 1);
            out.push(d[0].2.tokens[0]);
        }
        svc.retire(&handle);
        svc.shutdown();
        streams.push(out);
    }
    assert_eq!(streams[0], streams[1], "token stream must be shard-invariant");
}
