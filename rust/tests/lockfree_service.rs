//! Loom-style schedule-exploration stress tests for the lock-free shared
//! sampler pool (DESIGN.md §11).
//!
//! The real loom crate is unavailable offline, so interleavings are
//! explored the way the repo's proptests sweep cases: a seeded Philox
//! stream drives thread counts, ownership skew, injected yields, burst
//! depths, and crash times, and every case asserts the full contract —
//! no lost verdict, no duplicated verdict, streams bit-identical to a
//! single-threaded baseline — under concurrent submitters × stealing
//! workers × a respawning (crash-injected) worker. The quiescent-state
//! reclamation invariant (no slot reused while a reader holds a pin) is
//! driven directly against the public `TaskSlots` API.
//!
//! Ownership is deliberately skewed in most cases: every sequence id is
//! ≡ 0 (mod m), so one shard owns ALL the work and the other workers
//! only make progress by stealing — any bug where a stolen decision
//! diverges from the owner's (worker identity leaking into the keying)
//! breaks the stream comparisons loudly.

use simple_serve::config::{DecisionVariant, SamplerConfig};
use simple_serve::decision::service::{
    ColumnMeta, DecisionBatch, IterationTask, SamplerService,
};
use simple_serve::decision::slots::{claim_pack, TaskSlots};
use simple_serve::decision::{SamplingParams, SeqHandle};
use simple_serve::rng::Philox;
use simple_serve::tensor::{shard_row_major, ShardedLogits, Tensor2};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

const VOCAB: usize = 64;
const MAX_SEQ: usize = 128;

/// Deterministic logits for (namespace, iteration): both the threaded run
/// and the single-threaded baseline feed identical views, so the streams
/// must match bit-for-bit whatever the interleaving did.
fn logits_view(b: usize, key: u64, shards: usize) -> ShardedLogits {
    let data: Vec<f32> = (0..b * VOCAB)
        .map(|i| {
            let x = (i as u64)
                .wrapping_mul(2654435761)
                .wrapping_add(key.wrapping_mul(0x9E37_79B9));
            ((x % 1000) as f32) / 150.0 - 3.0
        })
        .collect();
    shard_row_major(&Tensor2::from_vec(b, VOCAB, data), shards)
}

fn service(m: usize, seed: u64) -> SamplerService {
    let cfg = SamplerConfig {
        num_samplers: m,
        variant: DecisionVariant::Offloading,
        seed,
        ..Default::default()
    };
    SamplerService::start(&cfg, None, MAX_SEQ)
}

/// One submitter's workload: its own disjoint sequences, its own task-id
/// namespace, `iters` iterations.
struct Lane {
    ns: u64,
    seq_ids: Vec<u64>,
}

fn lane_task(lane: &Lane, handles: &[SeqHandle], iter: u64) -> IterationTask {
    let b = lane.seq_ids.len();
    let columns: Vec<ColumnMeta> = lane
        .seq_ids
        .iter()
        .enumerate()
        .map(|(col, &seq_id)| ColumnMeta { col, seq_id, iteration: iter })
        .collect();
    let recs: Vec<Option<SeqHandle>> = handles.iter().cloned().map(Some).collect();
    let view = logits_view(b, lane.ns.wrapping_mul(1_000_003) ^ iter, 2);
    IterationTask::single((lane.ns << 48) | iter, view, columns, recs, Vec::new())
}

/// Single-threaded oracle: the same lanes driven sequentially on a fresh
/// m=1 pool. Decisions are keyed by (pool seed, request seed, sequence,
/// iteration) — never by worker identity or schedule — so this is the
/// ground truth every interleaving must reproduce.
fn baseline_streams(lanes: &[Lane], iters: u64, pool_seed: u64) -> HashMap<u64, Vec<u32>> {
    let svc = service(1, pool_seed);
    let mut streams: HashMap<u64, Vec<u32>> = HashMap::new();
    for lane in lanes {
        let handles: Vec<SeqHandle> = lane
            .seq_ids
            .iter()
            .map(|&s| {
                let params = SamplingParams { seed: s, ..SamplingParams::production_default() };
                svc.register(s, &[1, 2, 3], &params)
            })
            .collect();
        for iter in 0..iters {
            svc.submit(lane_task(lane, &handles, iter));
            let (decisions, _) = svc.collect((lane.ns << 48) | iter, lane.seq_ids.len());
            for (_, seq, verdict) in decisions {
                streams.entry(seq).or_default().extend(&verdict.tokens);
            }
        }
        for h in &handles {
            svc.retire(h);
        }
    }
    svc.shutdown();
    streams
}

/// Skewed lanes: every sequence id ≡ 0 (mod m), all owned by shard 0.
fn skewed_lanes(n_lanes: usize, b_per_lane: usize, m: usize) -> Vec<Lane> {
    (0..n_lanes)
        .map(|t| Lane {
            ns: t as u64 + 1,
            seq_ids: (0..b_per_lane)
                .map(|i| ((t * b_per_lane + i) * m) as u64)
                .collect(),
        })
        .collect()
}

#[test]
fn interleaved_submitters_with_forced_stealing_preserve_streams() {
    // N submitter threads burst-submit pipelined windows of tasks into one
    // pool whose ownership is 100% skewed onto shard 0, with seeded random
    // yields perturbing the schedule each case. Workers 1..m only decide
    // anything by stealing from ring 0; whatever the interleaving, the
    // collected streams must equal the single-threaded oracle and every
    // (task, column) must be decided exactly once.
    let stolen_total = AtomicU64::new(0);
    for case in 0..12u64 {
        let mut rng = Philox::substream(0x10CF ^ case, case);
        let m = 2 + rng.next_below(3) as usize; // 2..=4
        let n_lanes = 2 + rng.next_below(2) as usize; // 2..=3
        let b = 2 + rng.next_below(3) as usize; // 2..=4 seqs per lane
        let iters = 4 + rng.next_below(5); // 4..=8
        let window = 1 + rng.next_below(4); // pipelined burst depth 1..=4
        let pool_seed = 0xAB ^ case;
        let lanes = skewed_lanes(n_lanes, b, m);
        let want = baseline_streams(&lanes, iters, pool_seed);

        let svc = service(m, pool_seed);
        // per-lane yield budgets drawn OUTSIDE the threads so the case is
        // reproducible from its seed
        let jitter: Vec<u64> = (0..n_lanes).map(|_| rng.next_below(8)).collect();
        let mut got: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut decided_once: HashSet<(u64, usize)> = HashSet::new();
        std::thread::scope(|scope| {
            let mut joins = Vec::new();
            for (t, lane) in lanes.iter().enumerate() {
                let svc = &svc;
                let jit = jitter[t];
                joins.push(scope.spawn(move || {
                    let handles: Vec<SeqHandle> = lane
                        .seq_ids
                        .iter()
                        .map(|&s| {
                            let params = SamplingParams {
                                seed: s,
                                ..SamplingParams::production_default()
                            };
                            svc.register(s, &[1, 2, 3], &params)
                        })
                        .collect();
                    let mut streams: HashMap<u64, Vec<u32>> = HashMap::new();
                    let mut seen: HashSet<(u64, usize)> = HashSet::new();
                    let mut inflight: Vec<u64> = Vec::new();
                    let reap = |svc: &SamplerService,
                                    task: u64,
                                    streams: &mut HashMap<u64, Vec<u32>>,
                                    seen: &mut HashSet<(u64, usize)>| {
                        let done = loop {
                            if let Some(d) = svc.try_collect(task).expect("healthy pool") {
                                break d;
                            }
                            std::thread::yield_now();
                        };
                        assert_eq!(
                            done.decisions.len(),
                            lane.seq_ids.len(),
                            "task {task:#x}: no lost verdict"
                        );
                        for (col, seq, verdict) in done.decisions {
                            assert!(
                                seen.insert((task, col)),
                                "task {task:#x} col {col}: duplicated verdict"
                            );
                            streams.entry(seq).or_default().extend(&verdict.tokens);
                        }
                    };
                    for iter in 0..iters {
                        for _ in 0..(iter.wrapping_mul(jit) % 4) {
                            std::thread::yield_now(); // schedule perturbation
                        }
                        svc.submit(lane_task(lane, &handles, iter));
                        inflight.push((lane.ns << 48) | iter);
                        if inflight.len() as u64 >= window {
                            let task = inflight.remove(0);
                            reap(svc, task, &mut streams, &mut seen);
                        }
                    }
                    for task in inflight.drain(..) {
                        reap(svc, task, &mut streams, &mut seen);
                    }
                    for h in &handles {
                        svc.retire(h);
                    }
                    (streams, seen)
                }));
            }
            for j in joins {
                let (streams, seen) = j.join().expect("submitter lane");
                got.extend(streams);
                decided_once.extend(seen);
            }
        });
        let stats = svc.shutdown();
        // all work was owned by shard 0: any decision recorded by another
        // worker was a steal
        let stolen: u64 = stats.iter().skip(1).map(|s| s.decisions).sum();
        stolen_total.fetch_add(stolen, Ordering::Relaxed);
        assert_eq!(got, want, "case {case}: m={m} lanes={n_lanes} b={b} window={window}");
        assert_eq!(
            decided_once.len() as u64,
            n_lanes as u64 * iters * b as u64,
            "case {case}: exactly one verdict per (task, column)"
        );
    }
    // Schedules vary, but across 12 skewed-ownership cases the stealers
    // must have fired at least once — otherwise the test isn't exercising
    // the steal path at all.
    assert!(
        stolen_total.load(Ordering::Relaxed) > 0,
        "no case ever stole: the skew setup is broken"
    );
}

#[test]
fn crash_churn_loses_and_duplicates_nothing_across_incarnations() {
    // A killer thread injects worker crashes while the main thread streams
    // pipelined iterations through the pool: every respawn bumps the dead
    // worker's incarnation, releases its cell claims, and resubmits its
    // unanswered shard messages. The contract under that churn: every
    // (task, column) decided exactly once, every replay record's decided
    // length equals the iterations run, streams bit-identical to the
    // oracle, and recovery actually fired.
    for case in 0..8u64 {
        let mut rng = Philox::substream(0xDEAD ^ case, case);
        let m = 2 + rng.next_below(2) as usize; // 2..=3
        let b = 3 + rng.next_below(3) as usize; // 3..=5
        let iters = 10 + rng.next_below(8); // 10..=17
        let pool_seed = 0xC4A5 ^ case;
        let lanes = skewed_lanes(1, b, m);
        let want = baseline_streams(&lanes, iters, pool_seed);
        let lane = &lanes[0];

        let svc = service(m, pool_seed);
        let progress = AtomicU64::new(0);
        let stop = AtomicBool::new(false);
        // kill schedule drawn up front: (progress gate, victim) pairs at
        // least 2 collected iterations apart, so the per-worker crash-loop
        // breaker (reset at every assemble) never trips spuriously
        let mut kills: Vec<(u64, usize)> = Vec::new();
        let mut at = 1 + rng.next_below(2);
        while at + 2 < iters {
            kills.push((at, rng.next_below(m as u64) as usize));
            at += 2 + rng.next_below(3);
        }
        let mut got: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut decided_once: HashSet<(u64, usize)> = HashSet::new();
        let handles: Vec<SeqHandle> = lane
            .seq_ids
            .iter()
            .map(|&s| {
                let params =
                    SamplingParams { seed: s, ..SamplingParams::production_default() };
                svc.register(s, &[1, 2, 3], &params)
            })
            .collect();
        std::thread::scope(|scope| {
            let svc_ref = &svc;
            let progress_ref = &progress;
            let stop_ref = &stop;
            let kills_ref = &kills;
            let killer = scope.spawn(move || {
                for &(gate, victim) in kills_ref {
                    while progress_ref.load(Ordering::Acquire) < gate {
                        if stop_ref.load(Ordering::Acquire) {
                            return;
                        }
                        std::thread::yield_now();
                    }
                    svc_ref.inject_sampler_crash(victim);
                }
            });
            for iter in 0..iters {
                svc.submit(lane_task(lane, &handles, iter));
                let task = (lane.ns << 48) | iter;
                let done = svc.collect_checked(task).expect("recovery, not failure");
                assert_eq!(
                    done.decisions.len(),
                    lane.seq_ids.len(),
                    "case {case} task {task:#x}: no lost verdict"
                );
                for (col, seq, verdict) in done.decisions {
                    assert!(
                        decided_once.insert((task, col)),
                        "case {case} task {task:#x} col {col}: duplicated verdict"
                    );
                    got.entry(seq).or_default().extend(&verdict.tokens);
                }
                progress.fetch_add(1, Ordering::Release);
            }
            stop.store(true, Ordering::Release);
            killer.join().expect("killer thread");
        });
        // positional token log: exactly one commit per iteration survived
        // the incarnation churn (a double-apply would not change the value
        // — writes are idempotent by position — but a lost resubmission
        // would leave decided_len short)
        for (i, h) in handles.iter().enumerate() {
            assert_eq!(
                h.decided_len(),
                iters as usize,
                "case {case} seq {}: replay log complete",
                lane.seq_ids[i]
            );
        }
        for h in &handles {
            svc.retire(h);
        }
        assert!(
            svc.recovery_stats().respawns > 0,
            "case {case}: kills {kills:?} never fired"
        );
        svc.shutdown();
        assert_eq!(got, want, "case {case}: m={m} b={b} kills={kills:?}");
    }
}

#[test]
fn retire_reregister_churn_orphans_old_records_without_double_apply() {
    // Incarnation churn at the sequence level: a task still in flight when
    // its sequence is retired + re-registered may only touch the orphaned
    // old record (Arc identity IS the registration incarnation) — the
    // fresh record starts empty and its stream matches a churn-free run.
    for case in 0..6u64 {
        let mut rng = Philox::substream(0x0127 ^ case, case);
        let m = 1 + rng.next_below(3) as usize; // 1..=3
        let pool_seed = 0x11 ^ case;
        let params = SamplingParams { seed: 7, ..SamplingParams::production_default() };
        let mk_task = |iter: u64, ns: u64, h: &SeqHandle| {
            IterationTask::single(
                (ns << 48) | iter,
                logits_view(1, ns.wrapping_mul(1_000_003) ^ iter, 2),
                vec![ColumnMeta { col: 0, seq_id: 0, iteration: iter }],
                vec![Some(h.clone())],
                Vec::new(),
            )
        };

        // churn-free oracle for the SECOND incarnation's stream
        let oracle = {
            let svc = service(1, pool_seed);
            let h = svc.register(0, &[1, 2, 3], &params);
            let mut out = Vec::new();
            for iter in 0..4u64 {
                svc.submit(mk_task(iter, 2, &h));
                let (d, _) = svc.collect((2 << 48) | iter, 1);
                out.extend(&d[0].2.tokens);
            }
            svc.retire(&h);
            svc.shutdown();
            out
        };

        let svc = service(m, pool_seed);
        let old = svc.register(0, &[1, 2, 3], &params);
        // decide one iteration under the old incarnation…
        svc.submit(mk_task(0, 1, &old));
        let (d, _) = svc.collect(1 << 48, 1);
        assert_eq!(d.len(), 1);
        let old_decided = old.decided_len();
        assert_eq!(old_decided, 1);
        // …retire it and mint the next incarnation…
        svc.retire(&old);
        let fresh = svc.register(0, &[1, 2, 3], &params);
        assert!(!Arc::ptr_eq(&old, &fresh), "re-register mints a new record");
        assert_eq!(fresh.decided_len(), 0, "fresh record starts empty");
        // …then submit a STALE task still carrying the old handle (in the
        // engine: a microbatch submitted before the retire, reaped after
        // it) and run the fresh incarnation concurrently with it.
        svc.submit(mk_task(1, 1, &old));
        let mut fresh_stream = Vec::new();
        for iter in 0..4u64 {
            svc.submit(mk_task(iter, 2, &fresh));
            let done = svc.collect_checked((2 << 48) | iter).expect("healthy pool");
            for (_, _, verdict) in done.decisions {
                fresh_stream.extend(&verdict.tokens);
            }
        }
        // the stale task completes but decides nothing: its record is
        // retired, so the column is skipped — no double-apply, no hang
        let stale = svc.collect_checked((1 << 48) | 1).expect("stale task completes");
        assert!(stale.decisions.is_empty(), "case {case}: retired rec must decide nothing");
        assert_eq!(
            old.decided_len(),
            old_decided,
            "case {case}: orphaned record frozen after retire"
        );
        assert_eq!(fresh_stream, oracle, "case {case}: m={m}");
        svc.retire(&fresh);
        svc.shutdown();
    }
}

// ---- quiescent-state reclamation, driven on TaskSlots directly ----

fn empty_task(id: u64) -> Arc<IterationTask> {
    Arc::new(IterationTask {
        iter: id,
        mb: 0,
        views: Vec::new(),
        columns: Arc::new(Vec::new()),
        recs: Arc::new(Vec::new()),
        pre: Arc::new(Vec::new()),
        drafts: Arc::new(Vec::new()),
    })
}

fn empty_batch(iter: u64) -> DecisionBatch {
    DecisionBatch {
        iter,
        mb: 0,
        sampler_id: 0,
        decisions: Vec::new(),
        busy_s: 0.0,
        start_s: 0.0,
        end_s: 0.0,
    }
}

#[test]
fn pinned_slot_is_never_reclaimed_while_a_reader_holds_it() {
    // The QSR invariant on a one-slot table: after the collector retires
    // the slot, allocation must keep bouncing off it for as long as a
    // reader pin is outstanding, and succeed once the pin drops.
    let slots = TaskSlots::new(1, 1);
    let idx = slots.try_publish(empty_task(7)).unwrap_or_else(|_| panic!("empty table"));
    assert_eq!(idx, 0);
    let pin = slots.pin(0, 7).expect("published slot pins");
    assert!(slots.try_claim(0, 0, claim_pack(0, 1)));
    slots.publish_cell(0, 0, empty_batch(7));
    let taken = slots.try_take(7).expect("all cells reported");
    assert_eq!(taken.task.iter, 7);
    // slot is RETIRED but the pin is live: reclamation must back out
    for _ in 0..64 {
        assert!(
            slots.try_publish(empty_task(8)).is_err(),
            "slot reused while a reader holds it"
        );
    }
    drop(pin);
    let idx = slots.try_publish(empty_task(8)).unwrap_or_else(|_| panic!("pin quiesced"));
    assert_eq!(idx, 0);
}

#[test]
fn reclamation_waits_for_concurrent_reader_threads() {
    // Threaded version of the invariant: a reader thread holds the pin for
    // a signalled window while the main thread completes, takes, and spins
    // on re-allocation. The publish may only land after the reader
    // releases — checked by a flag the reader sets just before dropping.
    let slots = TaskSlots::new(1, 1);
    assert!(slots.try_publish(empty_task(7)).is_ok(), "empty table");
    let pinned = AtomicBool::new(false);
    let release = AtomicBool::new(false);
    let released = AtomicBool::new(false);
    std::thread::scope(|scope| {
        scope.spawn(|| {
            let pin = slots.pin(0, 7).expect("published slot pins");
            pinned.store(true, Ordering::Release);
            while !release.load(Ordering::Acquire) {
                std::thread::yield_now();
            }
            released.store(true, Ordering::Release);
            drop(pin);
        });
        while !pinned.load(Ordering::Acquire) {
            std::thread::yield_now();
        }
        assert!(slots.try_claim(0, 0, claim_pack(0, 1)));
        slots.publish_cell(0, 0, empty_batch(7));
        slots.try_take(7).expect("all cells reported");
        // a handful of attempts while pinned must all bounce
        for _ in 0..32 {
            assert!(slots.try_publish(empty_task(9)).is_err());
            std::thread::yield_now();
        }
        release.store(true, Ordering::Release);
        // now spin until the reclamation goes through; the reader flagged
        // `released` strictly before dropping, so success implies the pin
        // was gone
        loop {
            match slots.try_publish(empty_task(9)) {
                Ok(idx) => {
                    assert_eq!(idx, 0);
                    assert!(
                        released.load(Ordering::Acquire),
                        "slot reclaimed while the reader still held its pin"
                    );
                    break;
                }
                Err(_) => std::thread::yield_now(),
            }
        }
    });
}
