//! Loom model checking for the lock-free decision plane (DESIGN.md §15).
//!
//! Compiled and run only under `RUSTFLAGS="--cfg loom"` (`make loom`):
//! without the cfg this file is an empty test crate, and the loom
//! dependency is only resolved for the loom configuration. Each model
//! wraps a *bounded* scenario in `loom::model`, which exhaustively
//! explores thread interleavings (bounded by `LOOM_MAX_PREEMPTIONS`)
//! over the production types — the `util::sync` shim swaps
//! `std::sync::atomic` for loom's instrumented atomics, so these checks
//! run the exact code the release build ships, not a reimplementation.
//!
//! Two models are pinned regressions:
//! - [`slots_dead_claim_release_races_live_reclaim`] — the PR 6 bug
//!   class: crash recovery releasing a dead incarnation's cell claim
//!   while the respawned incarnation concurrently re-claims and
//!   publishes the same cell.
//! - [`flight_snapshot_never_torn`] — the PR 9 bug: a snapshot keeping
//!   record `seq == h2 - capacity` from a ring without the spare slot,
//!   which a concurrent writer could tear mid-copy.
//!
//! The pin/reclaim model ([`slots_pin_blocks_reclamation_and_collect`])
//! additionally verifies the store-buffering (Dekker) fix in
//! `decision/slots.rs`: loom's `UnsafeCell` access tracking fails the
//! run if `try_publish`'s init write ever overlaps a pinned reader's
//! task read — exactly the interleaving plain Acquire/Release admits.

#![allow(unexpected_cfgs)]
#![cfg(loom)]

use loom::thread;
use simple_serve::decision::seqrec::SeqRec;
use simple_serve::decision::service::{DecisionBatch, IterationTask};
use simple_serve::decision::slots::{claim_pack, TaskSlots};
use simple_serve::decision::SamplingParams;
use simple_serve::ringbuf::flight::FlightRing;
use simple_serve::ringbuf::{mpmc, spsc};
use std::sync::Arc;

fn empty_task(id: u64) -> Arc<IterationTask> {
    Arc::new(IterationTask {
        iter: id,
        mb: 0,
        views: Vec::new(),
        columns: Arc::new(Vec::new()),
        recs: Arc::new(Vec::new()),
        pre: Arc::new(Vec::new()),
        drafts: Arc::new(Vec::new()),
    })
}

fn empty_batch(iter: u64, sampler: usize) -> DecisionBatch {
    DecisionBatch {
        iter,
        mb: 0,
        sampler_id: sampler,
        decisions: Vec::new(),
        busy_s: 0.0,
        start_s: 0.0,
        end_s: 0.0,
    }
}

// ---------------------------------------------------------------------------
// MPMC ring (Vyukov): producer races, steal races, wraparound, close
// ---------------------------------------------------------------------------

#[test]
fn mpmc_two_producers_one_consumer_no_loss() {
    loom::model(|| {
        let r = mpmc::Ring::<u64>::new(2);
        let handles: Vec<_> = [1u64, 2]
            .into_iter()
            .map(|v| {
                let r = r.clone();
                thread::spawn(move || {
                    while r.try_push(v).is_err() {
                        thread::yield_now();
                    }
                })
            })
            .collect();
        let mut got = Vec::new();
        while got.len() < 2 {
            match r.try_pop() {
                Ok(v) => got.push(v),
                Err(_) => thread::yield_now(),
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        got.sort_unstable();
        assert_eq!(got, vec![1, 2], "every push surfaces exactly once");
    });
}

#[test]
fn mpmc_steal_vs_pop_exactly_once() {
    loom::model(|| {
        // Two items pre-published; the owner and a stealer race pops.
        let r = mpmc::Ring::<u64>::new(2);
        r.try_push(10).unwrap();
        r.try_push(20).unwrap();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let r = r.clone();
                thread::spawn(move || loop {
                    match r.try_pop() {
                        Ok(v) => return v,
                        Err(_) => thread::yield_now(),
                    }
                })
            })
            .collect();
        let mut got: Vec<u64> =
            consumers.into_iter().map(|h| h.join().unwrap()).collect();
        got.sort_unstable();
        assert_eq!(got, vec![10, 20], "each item popped by exactly one thread");
        assert!(r.try_pop().is_err(), "nothing left behind");
    });
}

#[test]
fn mpmc_wraparound_lap_reuse() {
    loom::model(|| {
        // 4 items through a capacity-2 ring: every slot serves two laps,
        // exercising the `seq = pos + mask + 1` retire arithmetic under a
        // concurrent producer.
        let r = mpmc::Ring::<u64>::new(2);
        let producer = {
            let r = r.clone();
            thread::spawn(move || {
                for i in 0..4u64 {
                    while r.try_push(i).is_err() {
                        thread::yield_now();
                    }
                }
            })
        };
        for expect in 0..4u64 {
            loop {
                match r.try_pop() {
                    Ok(v) => {
                        assert_eq!(v, expect, "FIFO across the wrap seam");
                        break;
                    }
                    Err(_) => thread::yield_now(),
                }
            }
        }
        producer.join().unwrap();
    });
}

#[test]
fn mpmc_close_drains_inflight_push() {
    loom::model(|| {
        // A push that claimed its slot before the close must still be
        // delivered; pops report Closed only once drained.
        let r = mpmc::Ring::<u64>::new(2);
        let producer = {
            let r = r.clone();
            thread::spawn(move || {
                r.try_push(1).unwrap();
                r.close();
            })
        };
        let mut got = Vec::new();
        loop {
            match r.try_pop() {
                Ok(v) => got.push(v),
                Err(mpmc::PopError::Closed) => break,
                Err(mpmc::PopError::Empty) => thread::yield_now(),
            }
        }
        producer.join().unwrap();
        assert_eq!(got, vec![1], "close never swallows a delivered push");
    });
}

// ---------------------------------------------------------------------------
// SPSC ring: concurrent transfer with close
// ---------------------------------------------------------------------------

#[test]
fn spsc_transfer_no_loss() {
    loom::model(|| {
        let (p, c) = spsc::ring::<u64>(2);
        let producer = thread::spawn(move || {
            for i in 0..3u64 {
                let mut item = i;
                while let Err(spsc::Full(back)) = p.try_push(item) {
                    item = back;
                    thread::yield_now();
                }
            }
            p.close();
        });
        let mut got = Vec::new();
        loop {
            match c.try_pop() {
                Ok(v) => got.push(v),
                Err(spsc::PopError::Closed) => break,
                Err(spsc::PopError::Empty) => thread::yield_now(),
            }
        }
        producer.join().unwrap();
        assert_eq!(got, vec![0, 1, 2], "in order, no loss, no duplication");
    });
}

// ---------------------------------------------------------------------------
// Task slot table: claims, pins vs. reclamation, recovery sweeps
// ---------------------------------------------------------------------------

#[test]
fn slots_claim_exactly_one_winner() {
    loom::model(|| {
        let slots = Arc::new(TaskSlots::new(1, 1));
        let idx = slots.try_publish(empty_task(1)).ok().expect("empty table");
        let racers: Vec<_> = (0..2)
            .map(|worker| {
                let slots = slots.clone();
                thread::spawn(move || {
                    let Some(pin) = slots.pin(idx, 1) else { return false };
                    let won = slots.try_claim(idx, 0, claim_pack(worker, 1));
                    if won {
                        slots.publish_cell(idx, 0, empty_batch(1, worker));
                    }
                    drop(pin);
                    won
                })
            })
            .collect();
        let wins: usize =
            racers.into_iter().map(|h| usize::from(h.join().unwrap())).sum();
        assert_eq!(wins, 1, "the claim CAS admits exactly one decider");
        let taken = slots.try_take(1).expect("the winner reported the cell");
        assert_eq!(taken.batches.len(), 1);
    });
}

/// PR 6 regression: recovery releasing a dead incarnation's claim while
/// the respawned incarnation concurrently re-claims and publishes the
/// same cell. The live claim (and the sibling's completed cell) must
/// survive the sweep, and the task must still collect exactly once.
#[test]
fn slots_dead_claim_release_races_live_reclaim() {
    loom::model(|| {
        let slots = Arc::new(TaskSlots::new(1, 2));
        let idx = slots.try_publish(empty_task(1)).ok().expect("empty table");
        {
            let pin = slots.pin(idx, 1).expect("published slot pins");
            // Worker 0 (incarnation 1) claims cell 0 and "dies" before
            // reporting; worker 1 completes cell 1 normally.
            assert!(slots.try_claim(idx, 0, claim_pack(0, 1)));
            assert!(slots.try_claim(idx, 1, claim_pack(1, 1)));
            slots.publish_cell(idx, 1, empty_batch(1, 1));
            drop(pin);
        }
        let sweeper = {
            let slots = slots.clone();
            thread::spawn(move || slots.sweep_dead_claims(claim_pack(0, 1)))
        };
        let respawn = {
            let slots = slots.clone();
            thread::spawn(move || loop {
                // The respawned incarnation can claim only after the
                // sweep released the dead claim word.
                if let Some(pin) = slots.pin(idx, 1) {
                    if slots.try_claim(idx, 0, claim_pack(0, 2)) {
                        slots.publish_cell(idx, 0, empty_batch(1, 0));
                        drop(pin);
                        return;
                    }
                    drop(pin);
                }
                thread::yield_now();
            })
        };
        let resub = sweeper.join().unwrap();
        respawn.join().unwrap();
        // The sweep lists cell 0 unless the respawn re-claimed it first —
        // either way it must list nothing else and hold a live task clone.
        assert!(resub.len() <= 1, "cell 1's live claim must survive the sweep");
        if let Some(r) = resub.first() {
            assert_eq!((r.shard, r.task.iter), (0, 1));
        }
        let taken = slots.try_take(1).expect("both cells reported");
        assert_eq!(taken.batches.len(), 2, "collected exactly once, both cells");
    });
}

/// The pin/reclaim Dekker pair plus collect-under-pin. Thread A sweeps
/// (pins the slot and clones the task through the cell); thread B
/// re-claims, publishes, collects, and then republishes the slot for a
/// new task. Loom verifies two things no unit test can: the SeqCst
/// protocol never lets B's `try_publish` init write overlap A's pinned
/// read (cell access tracking), and `try_take`'s clone-not-move keeps
/// A's task reference valid across B's collect.
#[test]
fn slots_pin_blocks_reclamation_and_collect() {
    loom::model(|| {
        let slots = Arc::new(TaskSlots::new(1, 1));
        let idx = slots.try_publish(empty_task(1)).ok().expect("empty table");
        {
            let pin = slots.pin(idx, 1).expect("published slot pins");
            // Worker 0 (incarnation 1) claims, then "dies" unreported.
            assert!(slots.try_claim(idx, 0, claim_pack(0, 1)));
            drop(pin);
        }
        let sweeper = {
            let slots = slots.clone();
            thread::spawn(move || {
                let resub = slots.sweep_dead_claims(claim_pack(0, 1));
                // The clone stays readable regardless of what the
                // collector on the other thread is doing to the slot.
                // A sweep scheduled after the collector's republish may
                // legitimately list task 2's still-unclaimed cell (the
                // claim CAS absorbs such duplicates); either way the
                // cloned task must be coherent.
                for r in &resub {
                    assert!(r.task.iter == 1 || r.task.iter == 2);
                }
                resub.len()
            })
        };
        let collector = {
            let slots = slots.clone();
            thread::spawn(move || {
                loop {
                    if let Some(pin) = slots.pin(idx, 1) {
                        if slots.try_claim(idx, 0, claim_pack(0, 2)) {
                            slots.publish_cell(idx, 0, empty_batch(1, 0));
                            drop(pin);
                            break;
                        }
                        drop(pin);
                    }
                    thread::yield_now();
                }
                let taken = slots.try_take(1).expect("cell reported");
                assert_eq!(taken.task.iter, 1);
                // Reuse the slot for a fresh task: must wait out the
                // sweeper's pin (quiescent-state reclamation), and its
                // init writes must never race the sweeper's reads.
                let mut task = empty_task(2);
                loop {
                    match slots.try_publish(task) {
                        Ok(i) => {
                            assert_eq!(i, idx);
                            break;
                        }
                        Err(back) => {
                            task = back;
                            thread::yield_now();
                        }
                    }
                }
            })
        };
        let listed = sweeper.join().unwrap();
        collector.join().unwrap();
        assert!(listed <= 1);
        assert!(slots.pin(idx, 2).is_some(), "fresh task published");
    });
}

// ---------------------------------------------------------------------------
// Per-sequence replay records: positional writes vs. high-water reads
// ---------------------------------------------------------------------------

#[test]
fn seqrec_write_vs_read_high_water() {
    loom::model(|| {
        let rec = SeqRec::new(7, &[1], &[], &SamplingParams::default(), None, 4);
        let writer = {
            let rec = rec.clone();
            thread::spawn(move || {
                rec.log_decided(0, &[10, 11]);
                rec.log_decided(2, &[12]);
            })
        };
        let expect = [10u32, 11, 12];
        loop {
            let n = rec.decided_len();
            let snap = rec.read_upto(n as u64);
            // Every token below the acquired high-water mark is published.
            for (i, &t) in snap.iter().enumerate() {
                assert_eq!(t, expect[i], "read below decided_len saw a torn write");
            }
            if n == 3 {
                break;
            }
            thread::yield_now();
        }
        writer.join().unwrap();
    });
}

// ---------------------------------------------------------------------------
// Flight ring: the PR 9 torn-record regression
// ---------------------------------------------------------------------------

/// A capacity-1 ring overwrites on every push, so every snapshot races an
/// in-flight overwrite. The seqlock validation must drop any record with
/// `seq < h2 - capacity` — the PR 9 bug kept `seq == h2 - capacity` from
/// a ring without the spare slot, and this model finds that tear.
#[test]
fn flight_snapshot_never_torn() {
    loom::model(|| {
        let ring: Arc<FlightRing<2>> = Arc::new(FlightRing::new(1));
        let writer = {
            let ring = ring.clone();
            thread::spawn(move || {
                for i in 0..3u64 {
                    ring.push(&[i, !i]);
                }
            })
        };
        for _ in 0..2 {
            let snap = ring.snapshot();
            assert!(snap.len() <= 1, "capacity-1 ring retains one record");
            for rec in &snap {
                assert_eq!(rec[1], !rec[0], "torn record survived snapshot");
            }
            thread::yield_now();
        }
        writer.join().unwrap();
        let final_snap = ring.snapshot();
        assert_eq!(final_snap, vec![[2, !2u64]], "quiescent: last record intact");
    });
}
