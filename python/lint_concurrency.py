#!/usr/bin/env python3
"""Source-level concurrency lint for the lock-free decision plane.

Runs in `make ci` without a Rust toolchain: the rules below are enforced
by scanning the Rust sources directly (comment/string-aware, but purely
lexical — no parser, no macro expansion). Three rules (DESIGN.md §15):

R1  unsafe-needs-safety   Every `unsafe` keyword (block, impl, trait)
                          must carry a `// SAFETY:` comment — on the same
                          line, anywhere within the statement, or in the
                          contiguous comment block immediately above the
                          statement.

R2  relaxed-needs-why     Every *mutating* atomic operation (store, swap,
                          fetch_*, compare_exchange*) whose arguments
                          mention `Ordering::Relaxed` — including a
                          Relaxed CAS failure ordering — must carry an
                          `// ordering:` comment explaining why relaxed
                          is sound. Pure loads are exempt: a mutating
                          relaxed op can silently unpublish data, a
                          relaxed load is at worst stale.
                          Files in ALLOWLIST_RELAXED (monotonic metrics
                          counters) are exempt wholesale.

R3  no-mutex-hot-path     Hot-path files (the submit/decide/collect path:
                          `decision/service.rs`, `decision/slots.rs`,
                          `ringbuf/*`) must not mention `Mutex`/`RwLock`
                          outside `#[cfg(test)]` modules and `use` lines,
                          unless the site carries a comment containing
                          "cold" (a documented cold-path waiver).

Usage:
    python3 python/lint_concurrency.py rust/src [--json out.json]

Exit status 1 when violations exist; diagnostics are `file:line:` lines.
Importable: `lint_source(text, relpath)` / `lint_tree(root)`.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

# Files whose relaxed mutations are exempt wholesale (R2): monotonic
# observability counters with no happens-before obligations.
ALLOWLIST_RELAXED = ("trace/metrics.rs",)

# Hot-path files for R3, matched as suffixes of the repo-relative path.
HOT_PATH_SUFFIXES = ("decision/service.rs", "decision/slots.rs")
HOT_PATH_DIRS = ("ringbuf/",)

# Mutating atomic operations (R2). Loads are deliberately absent.
MUTATING_OPS = (
    "store|swap|fetch_add|fetch_sub|fetch_or|fetch_and|fetch_xor|fetch_nand|"
    "fetch_max|fetch_min|fetch_update|compare_exchange_weak|compare_exchange"
)
MUTATING_RE = re.compile(r"\.(%s)\s*\(" % MUTATING_OPS)

UNSAFE_RE = re.compile(r"\bunsafe\b")
LOCK_RE = re.compile(r"\b(Mutex|RwLock)\b")
CHAR_LIT_RE = re.compile(r"'(\\.|[^\\'])'")


def split_code_comments(text: str) -> tuple[list[str], list[str]]:
    """Split source into per-line (code, comment-text) pairs.

    Strings and char literals are blanked out of the code stream (so
    tokens inside them never match a rule) and comment text is collected
    separately per line (so annotations can be searched). Block comments
    nest, as in Rust.
    """
    code_lines: list[str] = []
    comment_lines: list[str] = []
    code: list[str] = []
    comment: list[str] = []
    i = 0
    n = len(text)
    block_depth = 0  # /* */ nesting

    def endline() -> None:
        code_lines.append("".join(code))
        comment_lines.append("".join(comment))
        code.clear()
        comment.clear()

    while i < n:
        c = text[i]
        if c == "\n":
            endline()
            i += 1
            continue
        if block_depth > 0:
            if text.startswith("/*", i):
                block_depth += 1
                i += 2
            elif text.startswith("*/", i):
                block_depth -= 1
                i += 2
            else:
                comment.append(c)
                i += 1
            continue
        if text.startswith("//", i):
            j = text.find("\n", i)
            j = n if j < 0 else j
            comment.append(text[i + 2 : j].strip("/! "))
            i = j
            continue
        if text.startswith("/*", i):
            block_depth = 1
            i += 2
            continue
        if c == '"':
            # String literal (a preceding r#..# raw prefix was consumed
            # below); skip to the unescaped closing quote.
            i += 1
            while i < n:
                if text[i] == "\\":
                    i += 2
                elif text[i] == '"':
                    i += 1
                    break
                else:
                    if text[i] == "\n":
                        endline()
                    i += 1
            code.append('""')
            continue
        if c == "r" and i + 1 < n and text[i + 1] in "\"#":
            # Raw string r"..." / r#"..."#: find the matching close.
            j = i + 1
            hashes = 0
            while j < n and text[j] == "#":
                hashes += 1
                j += 1
            if j < n and text[j] == '"':
                close = '"' + "#" * hashes
                k = text.find(close, j + 1)
                k = n if k < 0 else k + len(close)
                for ch in text[i:k]:
                    if ch == "\n":
                        endline()
                code.append('""')
                i = k
                continue
        if c == "'":
            m = CHAR_LIT_RE.match(text, i)
            if m:
                code.append("''")
                i = m.end()
                continue
            # lifetime tick: keep as-is
        code.append(c)
        i += 1
    endline()
    return code_lines, comment_lines


def statement_start(code_lines: list[str], line: int) -> int:
    """First line of the statement containing `line` (0-based).

    Walks upward while the previous line is non-empty code that does not
    end a statement/block (`;`, `{`, `}`) — i.e. while `line` is a
    continuation of it.
    """
    s = line
    while s > 0:
        prev = code_lines[s - 1].strip()
        if not prev or prev.endswith((";", "{", "}")):
            break
        # Attribute lines start their own construct; don't walk past them.
        if prev.startswith("#["):
            break
        s -= 1
    return s


def has_annotation(
    code_lines: list[str],
    comment_lines: list[str],
    first: int,
    last: int,
    token: str,
) -> bool:
    """Is `token` present in a comment attached to lines [first, last]?

    Attached means: on any line of the statement/call itself, or in the
    contiguous comment-only block immediately above `first`.
    """
    token = token.lower()
    for ln in range(first, min(last + 1, len(comment_lines))):
        if token in comment_lines[ln].lower():
            return True
    ln = first - 1
    while ln >= 0 and not code_lines[ln].strip() and comment_lines[ln].strip():
        if token in comment_lines[ln].lower():
            return True
        ln -= 1
    return False


def test_module_lines(code_lines: list[str]) -> set[int]:
    """Lines (0-based) inside `#[cfg(test)] mod { ... }` blocks."""
    out: set[int] = set()
    n = len(code_lines)
    for ln in range(n):
        if "#[cfg(test)]" not in code_lines[ln]:
            continue
        # Find the `mod` item this attribute decorates and its brace span.
        m = ln
        while m < n and "mod " not in code_lines[m]:
            m += 1
            if m - ln > 4:  # attribute decorates something else
                m = -1
                break
        if m < 0:
            continue
        depth = 0
        opened = False
        for k in range(m, n):
            depth += code_lines[k].count("{") - code_lines[k].count("}")
            if "{" in code_lines[k]:
                opened = True
            if opened:
                out.add(k)
            if opened and depth <= 0:
                break
    return out


def call_span(code_lines: list[str], line: int, col: int) -> tuple[int, str]:
    """(last line, argument text) of the call whose `(` is at line:col."""
    depth = 0
    args: list[str] = []
    for ln in range(line, len(code_lines)):
        seg = code_lines[ln][col:] if ln == line else code_lines[ln]
        for ch in seg:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return ln, "".join(args)
            if depth >= 1:
                args.append(ch)
        args.append("\n")
    return len(code_lines) - 1, "".join(args)


def is_hot_path(relpath: str) -> bool:
    rp = relpath.replace("\\", "/")
    if any(rp.endswith(sfx) for sfx in HOT_PATH_SUFFIXES):
        return True
    return any(("/" + d) in ("/" + rp) for d in HOT_PATH_DIRS)


def is_relaxed_allowlisted(relpath: str) -> bool:
    rp = relpath.replace("\\", "/")
    return any(rp.endswith(sfx) for sfx in ALLOWLIST_RELAXED)


def lint_source(text: str, relpath: str) -> dict:
    """Lint one file's source. Returns {violations, waivers, allowlisted}."""
    code_lines, comment_lines = split_code_comments(text)
    violations: list[dict] = []
    waivers: list[dict] = []
    allowlisted: list[dict] = []
    tests = test_module_lines(code_lines)

    def report(rule: str, line: int, message: str) -> None:
        violations.append(
            {"rule": rule, "file": relpath, "line": line + 1, "message": message}
        )

    # --- R1: unsafe needs SAFETY -----------------------------------------
    seen_stmts: set[int] = set()
    for ln, code in enumerate(code_lines):
        if not UNSAFE_RE.search(code):
            continue
        first = statement_start(code_lines, ln)
        if first in seen_stmts:
            continue
        seen_stmts.add(first)
        # The statement may span several lines; scan to its end (the next
        # line whose code ends with ; or { or } at or after `ln`).
        last = ln
        while last + 1 < len(code_lines):
            stripped = code_lines[last].strip()
            if stripped.endswith((";", "{", "}")):
                break
            last += 1
        if not has_annotation(code_lines, comment_lines, first, last, "safety:"):
            report(
                "unsafe-needs-safety",
                ln,
                "`unsafe` without a `// SAFETY:` comment",
            )

    # --- R2: mutating Relaxed needs an ordering comment -------------------
    allow_relaxed = is_relaxed_allowlisted(relpath)
    for ln, code in enumerate(code_lines):
        for m in MUTATING_RE.finditer(code):
            open_col = code.index("(", m.end() - 1)
            last, args = call_span(code_lines, ln, open_col)
            if "Relaxed" not in args:
                continue
            if allow_relaxed:
                allowlisted.append(
                    {"rule": "relaxed-needs-why", "file": relpath, "line": ln + 1}
                )
                continue
            first = statement_start(code_lines, ln)
            if has_annotation(code_lines, comment_lines, first, last, "ordering:"):
                continue
            report(
                "relaxed-needs-why",
                ln,
                "mutating atomic op with Ordering::Relaxed lacks an "
                "`// ordering:` comment",
            )

    # --- R3: no locks on hot-path files -----------------------------------
    if is_hot_path(relpath):
        for ln, code in enumerate(code_lines):
            if ln in tests:
                continue
            m = LOCK_RE.search(code)
            if not m:
                continue
            if code.lstrip().startswith("use ") or code.lstrip().startswith("pub use "):
                continue
            first = statement_start(code_lines, ln)
            if has_annotation(code_lines, comment_lines, first, ln, "cold"):
                waivers.append(
                    {
                        "rule": "no-mutex-hot-path",
                        "file": relpath,
                        "line": ln + 1,
                        "token": m.group(1),
                    }
                )
                continue
            report(
                "no-mutex-hot-path",
                ln,
                f"`{m.group(1)}` on a hot-path file without a cold-path "
                "waiver comment",
            )

    return {"violations": violations, "waivers": waivers, "allowlisted": allowlisted}


def lint_tree(root: str | Path) -> dict:
    """Lint every `.rs` file under `root`. Returns the merged report."""
    root = Path(root)
    report = {"violations": [], "waivers": [], "allowlisted": [], "files": 0}
    for path in sorted(root.rglob("*.rs")):
        rel = path.relative_to(root).as_posix()
        result = lint_source(path.read_text(encoding="utf-8"), rel)
        report["files"] += 1
        for key in ("violations", "waivers", "allowlisted"):
            report[key].extend(result[key])
    return report


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("root", help="directory of Rust sources (e.g. rust/src)")
    ap.add_argument("--json", help="write the full JSON report here")
    args = ap.parse_args(argv)

    report = lint_tree(args.root)
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report, indent=2) + "\n", encoding="utf-8")

    for v in report["violations"]:
        print(f"{v['file']}:{v['line']}: [{v['rule']}] {v['message']}")
    nv = len(report["violations"])
    print(
        f"lint_concurrency: {report['files']} files, {nv} violations, "
        f"{len(report['waivers'])} waivers, "
        f"{len(report['allowlisted'])} allowlisted relaxed sites"
    )
    return 1 if nv else 0


if __name__ == "__main__":
    sys.exit(main())
