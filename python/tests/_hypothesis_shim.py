"""Deterministic mini-sweep fallback for `hypothesis` (offline containers).

`test_kernels.py` uses a small slice of the hypothesis API:
`@settings(max_examples=N, deadline=None)`, `@given(**strategies)`, and the
strategies `st.integers(lo, hi)` / `st.sampled_from(seq)`. When hypothesis
is not installed, this shim replays the same decorator surface as a
seeded deterministic sweep: each strategy draws from a fixed-seed
`random.Random`, and the wrapped test runs `max_examples` times. No
shrinking, no database — just coverage, reproducibly.
"""

import random
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


class st:  # noqa: N801 - mirrors `strategies as st`
    @staticmethod
    def integers(lo, hi):
        return _Strategy(lambda rng: rng.randint(lo, hi))

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: rng.choice(seq))


def settings(max_examples=20, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        inner = fn

        def runner(*args, **kwargs):
            n = getattr(runner, "_shim_max_examples", None) or getattr(
                inner, "_shim_max_examples", 20
            )
            # str hashes are salted per process; crc32 keeps runs identical
            rng = random.Random(0xC0FFEE ^ zlib.crc32(inner.__name__.encode()))
            for case in range(n):
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    inner(*args, **drawn, **kwargs)
                except Exception:
                    print(f"shim case {case} failed with {drawn!r}")
                    raise

        # copy identity but NOT __wrapped__: pytest must see a zero-arg
        # signature, not the strategy parameters (they'd look like fixtures)
        runner.__name__ = inner.__name__
        runner.__doc__ = inner.__doc__
        runner.__module__ = inner.__module__
        return runner

    return deco
