"""L1 kernel correctness: Pallas (interpret mode) vs pure-jnp oracles.

Hypothesis sweeps shapes; every case asserts allclose against ref.py —
the CORE correctness signal for the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic mini-sweep fallback
    from _hypothesis_shim import given, settings, st

from compile.kernels.attention import decode_attention
from compile.kernels.lm_head import lm_head, mxu_utilization_estimate, vmem_bytes
from compile.kernels.ref import ref_decode_attention, ref_lm_head

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape):
    return rng.normal(0.0, 1.0, shape).astype(np.float32)


# ---------------------------------------------------------------- lm_head


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 8),
    d=st.sampled_from([16, 64, 128]),
    v_blocks=st.integers(1, 4),
    block_v=st.sampled_from([64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lm_head_matches_ref(b, d, v_blocks, block_v, seed):
    rng = np.random.default_rng(seed)
    v = v_blocks * block_v
    x = rand(rng, b, d)
    w = rand(rng, d, v) * (1.0 / d**0.5)
    tau = rng.uniform(0.3, 2.0, b).astype(np.float32)
    hot = (rng.uniform(size=v) < 0.3).astype(np.float32)

    bias = rand(rng, v) * 0.5
    logits, stats = lm_head(x, w, bias, tau, hot, block_v=block_v)
    ref_logits, ref_stats = ref_lm_head(x, w, bias, tau, hot)

    np.testing.assert_allclose(logits, ref_logits, rtol=1e-5, atol=1e-5)
    # z_max exact-ish, sums to fp32 accumulation tolerance
    np.testing.assert_allclose(stats[:, 0], ref_stats[:, 0], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(stats[:, 1], ref_stats[:, 1], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(stats[:, 2], ref_stats[:, 2], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(stats[:, 3], ref_stats[:, 3], rtol=1e-4, atol=1e-6)


def test_lm_head_single_block():
    # block_v >= V: one grid step, init + accumulate in the same call.
    rng = np.random.default_rng(0)
    x, w = rand(rng, 2, 8), rand(rng, 8, 32)
    tau = np.ones(2, np.float32)
    hot = np.zeros(32, np.float32)
    hot[:4] = 1.0
    bias = rand(rng, 32)
    logits, stats = lm_head(x, w, bias, tau, hot, block_v=64)
    ref_logits, ref_stats = ref_lm_head(x, w, bias, tau, hot)
    np.testing.assert_allclose(logits, ref_logits, rtol=1e-5)
    np.testing.assert_allclose(stats, ref_stats, rtol=1e-4, atol=1e-6)


def test_lm_head_stats_semantics():
    # Hand-checkable: uniform logits, half-hot mask.
    x = np.ones((1, 4), np.float32)
    w = np.zeros((4, 8), np.float32)  # all logits 0
    tau = np.ones(1, np.float32)
    hot = np.array([1, 1, 1, 1, 0, 0, 0, 0], np.float32)
    bias = np.zeros(8, np.float32)
    logits, stats = lm_head(x, w, bias, tau, hot, block_v=4)
    assert np.allclose(logits, 0.0)
    z_max, s_hot, s_tail, t_max = stats[0]
    assert z_max == 0.0
    assert np.isclose(s_hot, 4.0)  # four hot tokens, each w = exp(0) = 1
    assert np.isclose(s_tail, 4.0)
    assert np.isclose(t_max, 1.0)


def test_lm_head_extreme_logits_stable():
    rng = np.random.default_rng(3)
    x = rand(rng, 2, 16) * 100.0  # huge activations -> huge logits
    w = rand(rng, 16, 64)
    tau = np.full(2, 0.5, np.float32)
    hot = (np.arange(64) < 16).astype(np.float32)
    bias = rand(rng, 64)
    logits, stats = lm_head(x, w, bias, tau, hot, block_v=16)
    assert np.all(np.isfinite(stats)), stats
    ref_logits, ref_stats = ref_lm_head(x, w, bias, tau, hot)
    np.testing.assert_allclose(stats[:, 0], ref_stats[:, 0], rtol=1e-6)
    # weights are exp-normalized; sums stay finite and close
    np.testing.assert_allclose(stats[:, 1:], ref_stats[:, 1:], rtol=1e-3, atol=1e-6)


def test_perf_estimators_sane():
    assert vmem_bytes(8, 256, 2048) < 16 * 1024 * 1024  # fits VMEM
    assert 0.0 < mxu_utilization_estimate(8, 256, 2048) <= 1.0
    assert mxu_utilization_estimate(128, 128, 128) == 1.0


# ------------------------------------------------------------- attention


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    kvh=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2]),
    dh=st.sampled_from([8, 16]),
    t=st.sampled_from([4, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, kvh, group, dh, t, seed):
    rng = np.random.default_rng(seed)
    h = kvh * group
    q = rand(rng, b, h, dh)
    k = rand(rng, b, t, kvh, dh)
    v = rand(rng, b, t, kvh, dh)
    lengths = rng.integers(1, t + 1, b).astype(np.int32)

    out = decode_attention(q, k, v, lengths)
    ref = ref_decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_attention_masks_invalid_cache():
    # Garbage beyond `lengths` must not affect the output.
    rng = np.random.default_rng(1)
    q = rand(rng, 1, 2, 8)
    k1 = rand(rng, 1, 16, 2, 8)
    v1 = rand(rng, 1, 16, 2, 8)
    k2, v2 = k1.copy(), v1.copy()
    k2[:, 4:] = 999.0
    v2[:, 4:] = -999.0
    lengths = np.array([4], np.int32)
    out1 = decode_attention(q, k1, v1, lengths)
    out2 = decode_attention(q, k2, v2, lengths)
    np.testing.assert_allclose(out1, out2, rtol=1e-6)


def test_attention_length_one_attends_only_first():
    rng = np.random.default_rng(2)
    q = rand(rng, 1, 2, 4)
    k = rand(rng, 1, 8, 2, 4)
    v = rand(rng, 1, 8, 2, 4)
    out = decode_attention(q, k, v, np.array([1], np.int32))
    # with one valid position, attention output == v[:, 0] per head
    expect = v[:, 0]  # [1, KVH, Dh] == [1, H, Dh] here (group=1)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)


def test_attention_gqa_groups_share_kv():
    # H=4, KVH=2: heads (0,1) use kv head 0, (2,3) use kv head 1.
    rng = np.random.default_rng(4)
    b, t, kvh, dh = 1, 4, 2, 8
    k = rand(rng, b, t, kvh, dh)
    v = rand(rng, b, t, kvh, dh)
    q = rand(rng, b, 4, dh)
    q[0, 1] = q[0, 0]  # identical queries in the same group
    out = decode_attention(q, k, v, np.array([t], np.int32))
    np.testing.assert_allclose(out[0, 0], out[0, 1], rtol=1e-6)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
