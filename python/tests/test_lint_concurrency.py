"""Unit tests for the concurrency lint (python/lint_concurrency.py).

Each fixture is a minimal Rust snippet exercising one rule edge; the final
test runs the lint over the real tree and requires zero violations — the
gate `make lint` enforces in CI.
"""

import json
import os
import textwrap

from lint_concurrency import lint_source, lint_tree, main

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def rs(snippet: str) -> str:
    return textwrap.dedent(snippet)


def violations(text: str, relpath: str = "foo.rs"):
    return lint_source(rs(text), relpath)["violations"]


def rules(text: str, relpath: str = "foo.rs"):
    return [v["rule"] for v in violations(text, relpath)]


# --- R1: unsafe-needs-safety -------------------------------------------------


def test_unsafe_without_safety_flagged():
    vs = violations(
        """
        fn f(p: *const u32) -> u32 {
            unsafe { *p }
        }
        """
    )
    assert [v["rule"] for v in vs] == ["unsafe-needs-safety"]
    assert vs[0]["line"] == 3


def test_unsafe_with_safety_above_passes():
    assert not violations(
        """
        fn f(p: *const u32) -> u32 {
            // SAFETY: caller guarantees p is valid and aligned.
            unsafe { *p }
        }
        """
    )


def test_unsafe_with_same_line_safety_passes():
    assert not violations(
        """
        fn f(p: *const u32) -> u32 {
            unsafe { *p } // SAFETY: caller contract.
        }
        """
    )


def test_unsafe_impl_needs_safety():
    assert rules(
        """
        unsafe impl Send for Foo {}
        """
    ) == ["unsafe-needs-safety"]
    assert not violations(
        """
        // SAFETY: all fields are atomics; cross-thread access is synchronized
        // by the slot state machine.
        unsafe impl Send for Foo {}
        """
    )


def test_unsafe_in_string_or_comment_not_flagged():
    assert not violations(
        """
        fn f() {
            let s = "unsafe { nope }";
            // this mentions unsafe but is a comment
            let _ = s;
        }
        """
    )


def test_multiline_statement_annotation_reaches_unsafe_line():
    # SAFETY on the comment block above a statement whose `unsafe` sits on
    # a continuation line.
    assert not violations(
        """
        fn f(c: &Cell) {
            // SAFETY: exclusive by state machine.
            let v = c
                .with_mut(|p| unsafe { (*p).take() });
            let _ = v;
        }
        """
    )


# --- R2: relaxed-needs-why ---------------------------------------------------


def test_relaxed_store_without_comment_flagged():
    assert rules(
        """
        fn f(a: &AtomicU64) {
            a.store(1, Ordering::Relaxed);
        }
        """
    ) == ["relaxed-needs-why"]


def test_relaxed_store_with_ordering_comment_passes():
    assert not violations(
        """
        fn f(a: &AtomicU64) {
            // ordering: Relaxed — advisory counter, no reader depends on it.
            a.store(1, Ordering::Relaxed);
        }
        """
    )


def test_relaxed_load_is_exempt():
    assert not violations(
        """
        fn f(a: &AtomicU64) -> u64 {
            a.load(Ordering::Relaxed)
        }
        """
    )


def test_multiline_cas_with_relaxed_failure_detected():
    text = """
        fn f(a: &AtomicBool) {
            a.compare_exchange(
                false,
                true,
                Ordering::Acquire,
                Ordering::Relaxed,
            )
            .ok();
        }
        """
    assert rules(text) == ["relaxed-needs-why"]
    assert not violations(
        """
        fn f(a: &AtomicBool) {
            // ordering: Acquire pairs with the release; Relaxed failure is
            // fine — a lost race reads nothing through the flag.
            a.compare_exchange(
                false,
                true,
                Ordering::Acquire,
                Ordering::Relaxed,
            )
            .ok();
        }
        """
    )


def test_non_relaxed_rmw_passes_without_comment():
    assert not violations(
        """
        fn f(a: &AtomicU64) {
            a.fetch_add(1, Ordering::AcqRel);
        }
        """
    )


def test_allowlisted_file_reports_but_passes():
    res = lint_source(
        rs(
            """
            fn f(a: &AtomicU64) {
                a.fetch_add(1, Ordering::Relaxed);
            }
            """
        ),
        "trace/metrics.rs",
    )
    assert not res["violations"]
    assert len(res["allowlisted"]) == 1


# --- R3: no-mutex-hot-path ---------------------------------------------------


def test_mutex_on_hot_path_flagged():
    assert rules(
        """
        struct S {
            m: Mutex<Vec<u32>>,
        }
        """,
        "decision/slots.rs",
    ) == ["no-mutex-hot-path"]


def test_mutex_off_hot_path_passes():
    assert not violations(
        """
        struct S {
            m: Mutex<Vec<u32>>,
        }
        """,
        "engine/core.rs",
    )


def test_use_line_exempt_on_hot_path():
    assert not violations(
        """
        use std::sync::{Arc, Mutex};
        """,
        "ringbuf/mod.rs",
    )


def test_cold_waiver_on_hot_path():
    res = lint_source(
        rs(
            """
            struct S {
                // cold: refill path only, never on submit/decide/collect.
                m: Mutex<Vec<u32>>,
            }
            """
        ),
        "ringbuf/mod.rs",
    )
    assert not res["violations"]
    assert len(res["waivers"]) == 1
    assert res["waivers"][0]["token"] == "Mutex"


def test_rwlock_also_flagged():
    assert rules(
        """
        struct S {
            m: RwLock<u32>,
        }
        """,
        "decision/service.rs",
    ) == ["no-mutex-hot-path"]


def test_test_module_ignored_on_hot_path():
    assert not violations(
        """
        struct S {
            x: u32,
        }

        #[cfg(test)]
        mod tests {
            use std::sync::Mutex;

            #[test]
            fn t() {
                let m = Mutex::new(1);
                let _ = m.lock();
            }
        }
        """,
        "ringbuf/mpmc.rs",
    )


# --- tree / CLI ---------------------------------------------------------------


def test_lint_tree_json_shape(tmp_path):
    src = tmp_path / "decision"
    src.mkdir()
    (src / "slots.rs").write_text("fn f(p: *const u8) { unsafe { p.read() }; }\n")
    report = lint_tree(tmp_path)
    assert set(report) == {"violations", "waivers", "allowlisted", "files"}
    assert report["files"] == 1
    (v,) = report["violations"]
    assert set(v) == {"rule", "file", "line", "message"}
    assert v["file"] == "decision/slots.rs"


def test_cli_exit_codes_and_json(tmp_path):
    bad = tmp_path / "bad"
    bad.mkdir()
    (bad / "a.rs").write_text("fn f(a: &A) { a.store(1, Ordering::Relaxed); }\n")
    out = tmp_path / "report.json"
    assert main([str(bad), "--json", str(out)]) == 1
    assert len(json.loads(out.read_text())["violations"]) == 1

    good = tmp_path / "good"
    good.mkdir()
    (good / "a.rs").write_text("fn f() {}\n")
    assert main([str(good)]) == 0


def test_real_tree_has_zero_violations():
    report = lint_tree(os.path.join(REPO, "rust", "src"))
    assert report["files"] > 0
    assert report["violations"] == [], report["violations"]
