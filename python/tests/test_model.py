"""L2 model tests: shapes, KV-cache semantics, determinism, Zipf-ish logits."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_lib
from compile.kernels.ref import ref_lm_head

jax.config.update("jax_platform_name", "cpu")

CFG = model_lib.MICRO_TEST


@pytest.fixture(scope="module")
def weights():
    return {k: jnp.asarray(v) for k, v in model_lib.init_weights(CFG).items()}


def step_inputs(positions):
    b, t = CFG.batch, CFG.max_seq
    kv = (CFG.layers, b, t, CFG.kv_heads, CFG.head_dim)
    return {
        "ids": jnp.arange(b, dtype=jnp.int32) % CFG.vocab,
        "positions": jnp.asarray(positions, jnp.int32),
        "kv_k": jnp.zeros(kv, jnp.float32),
        "kv_v": jnp.zeros(kv, jnp.float32),
        "tau": jnp.ones(b, jnp.float32),
        "hot_mask": (jnp.arange(CFG.vocab) < 100).astype(jnp.float32),
    }


def test_decode_step_shapes(weights):
    inp = step_inputs([0] * CFG.batch)
    logits, stats, kv_k, kv_v = model_lib.decode_step(weights, **inp, cfg=CFG)
    assert logits.shape == (CFG.batch, CFG.vocab)
    assert stats.shape == (CFG.batch, 4)
    assert kv_k.shape == (CFG.layers, CFG.batch, CFG.max_seq, CFG.kv_heads, CFG.head_dim)
    assert kv_v.shape == kv_k.shape
    assert np.all(np.isfinite(logits))
    assert np.all(np.isfinite(stats))


def test_kv_write_is_positional(weights):
    # Step at position p must write K/V rows only at index p.
    positions = [3, 0, 5, 1]
    inp = step_inputs(positions)
    _, _, kv_k, _ = model_lib.decode_step(weights, **inp, cfg=CFG)
    kv_k = np.asarray(kv_k)
    for b, p in enumerate(positions):
        written = np.abs(kv_k[:, b]).sum(axis=(1, 2))  # [T] per layer summed later
        for l in range(CFG.layers):
            row_norms = np.abs(kv_k[l, b]).sum(axis=(1, 2))
            assert row_norms[p] > 0, f"layer {l} seq {b} row {p} not written"
            mask = np.ones(CFG.max_seq, bool)
            mask[p] = False
            assert np.allclose(row_norms[mask], 0.0), f"extra rows written b={b}"
        del written


def test_stats_match_ref_lm_head(weights):
    # The in-graph stats must equal recomputing ref_lm_head on the final
    # hidden state — verified indirectly: recompute from the returned logits.
    inp = step_inputs([0] * CFG.batch)
    logits, stats, _, _ = model_lib.decode_step(weights, **inp, cfg=CFG)
    logits = np.asarray(logits)
    tau = np.asarray(inp["tau"])
    hot = np.asarray(inp["hot_mask"])
    z_max = logits.max(axis=1)
    w = np.exp((logits - z_max[:, None]) / tau[:, None])
    s_hot = (w * hot[None, :]).sum(axis=1)
    s_tail = (w * (1 - hot[None, :])).sum(axis=1)
    np.testing.assert_allclose(stats[:, 0], z_max, rtol=1e-5)
    np.testing.assert_allclose(stats[:, 1], s_hot, rtol=1e-3)
    np.testing.assert_allclose(stats[:, 2], s_tail, rtol=1e-3)


def test_determinism(weights):
    inp = step_inputs([2] * CFG.batch)
    a = model_lib.decode_step(weights, **inp, cfg=CFG)[0]
    b = model_lib.decode_step(weights, **inp, cfg=CFG)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_logits_are_zipf_ish(weights):
    """§5.3 premise: softmax of the logits concentrates mass in a head.

    With the rank-tilted lm_head, a small top fraction of the vocab should
    carry most of the probability mass."""
    inp = step_inputs([0] * CFG.batch)
    logits, _, _, _ = model_lib.decode_step(weights, **inp, cfg=CFG)
    logits = np.asarray(logits, np.float64)
    p = np.exp(logits - logits.max(axis=1, keepdims=True))
    p /= p.sum(axis=1, keepdims=True)
    top_frac = int(CFG.vocab * 0.2)
    head_mass = np.sort(p, axis=1)[:, ::-1][:, :top_frac].sum(axis=1).mean()
    assert head_mass > 0.5, f"head mass {head_mass}"


def test_flat_wrapper_matches_dict_call(weights):
    inp = step_inputs([1] * CFG.batch)
    f = model_lib.decode_step_flat(CFG)
    flat_args = [weights[n] for n in model_lib.weight_names(CFG)] + [
        inp["ids"], inp["positions"], inp["kv_k"], inp["kv_v"], inp["tau"],
        inp["hot_mask"],
    ]
    out_flat = f(*flat_args)
    out_dict = model_lib.decode_step(weights, **inp, cfg=CFG)
    for a, b in zip(out_flat, out_dict):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_example_args_align_with_flat():
    args = model_lib.example_args(CFG)
    names = model_lib.weight_names(CFG)
    shapes = model_lib.weight_shapes(CFG)
    assert len(args) == len(names) + 6
    for n, a in zip(names, args):
        assert tuple(shapes[n]) == a.shape


def test_weight_init_deterministic():
    w1 = model_lib.init_weights(CFG)
    w2 = model_lib.init_weights(CFG)
    for k in w1:
        np.testing.assert_array_equal(w1[k], w2[k])
