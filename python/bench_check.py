#!/usr/bin/env python3
"""Gate decision-plane bench throughput against the committed baseline.

`make bench-check` runs the microbenchmarks into a fresh JSON file and
compares the gated cases (the shared-pool cluster group and the fused
dense-kernel pair) against the committed ``BENCH_decision.json``: a drop
in ``items_per_sec`` beyond the tolerance (default 15%) fails the build,
so a regression that re-grows the shared-pool contention cliff is caught
at PR time. The kernel pair additionally carries an absolute floor,
measured on the *fresh* run alone: the SIMD single-pass column kernel
must be at least 1.5x the scalar reference on the 32k-vocab group
(DESIGN.md §12), or the vectorization has rotted. The kvcache group
carries the same kind of floor: a prefix-cache hit admission must be at
least 5x a miss (DESIGN.md §13), or sharing has stopped skipping the
materialization work. The trace pair carries a ceiling instead: the
flight recorder may cost at most 10% on the shared-pool hot path with
tracing ON, and tracing OFF rides the ordinary baseline comparison so a
regression in the disabled gate is caught too (DESIGN.md §14). Every
violated floor is reported in one run.

The committed baseline may be *provisional* — synthesized on a machine
that could not run the benches (marked by a ``_baseline/provisional``
entry, or by gated cases carrying ``null`` throughput). A provisional
baseline never fails the gate; it prints the fresh numbers and asks to be
promoted. Promote real numbers with::

    python python/bench_check.py BENCH_decision.json fresh.json --promote

which replaces the baseline file with the fresh results (dropping the
provisional marker), arming the gate for subsequent runs.

Stdlib only — no third-party imports.
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys

# Case-name prefixes the gate enforces. Everything else is informational.
GATED_PREFIXES = ("cluster/shared_pool", "kernels/", "kvcache/", "trace/")
PROVISIONAL_MARKER = "_baseline/provisional"
DEFAULT_TOLERANCE = 0.15

# Absolute floor on the fused dense-kernel pair: the SIMD column kernel
# must beat the scalar reference by this factor on the fresh run. This
# check is independent of the committed baseline (and of its provisional
# state) — both numbers come from the same machine, same run.
KERNEL_SCALAR = "kernels/scalar_penalty_filter_softmax"
KERNEL_SIMD = "kernels/simd_penalty_filter_softmax"
MIN_KERNEL_SPEEDUP = 1.5

# Absolute floor on the radix prefix cache: a hit admission (share the
# published stem, materialize only the private tail) must beat a miss
# (materialize everything) by this factor on the fresh run (DESIGN.md
# §13). Same-machine, same-run, baseline-independent — like the kernel
# floor above.
CACHE_HIT = "kvcache/prefix_hit"
CACHE_MISS = "kvcache/prefix_miss"
MIN_CACHE_SPEEDUP = 5.0

# Ceiling on flight-recorder overhead (DESIGN.md §14): the same
# shared-pool submit/collect loop with tracing on must stay within this
# fraction of the tracing-off rate. Fresh-run-only, like the floors above
# ("off" additionally rides the baseline comparison, so a regression in
# the disabled gate itself — the one every untraced run pays — is caught
# against the committed numbers).
TRACE_OFF = "trace/off"
TRACE_ON = "trace/on"
MAX_TRACE_OVERHEAD = 0.10


def load_cases(path: str) -> dict[str, float | None]:
    """name -> items_per_sec (None when the case reported no rate)."""
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if not isinstance(data, list):
        raise SystemExit(f"{path}: expected a JSON array of bench cases")
    out: dict[str, float | None] = {}
    for case in data:
        name = case.get("name")
        if not isinstance(name, str):
            raise SystemExit(f"{path}: bench case without a name: {case!r}")
        out[name] = case.get("items_per_sec")
    return out


def gated(cases: dict[str, float | None]) -> dict[str, float | None]:
    return {
        name: ips
        for name, ips in cases.items()
        if any(name.startswith(p) for p in GATED_PREFIXES)
    }


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed baseline JSON (BENCH_decision.json)")
    ap.add_argument("fresh", help="freshly measured bench JSON")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=DEFAULT_TOLERANCE,
        help="allowed fractional items/s drop before failing (default 0.15)",
    )
    ap.add_argument(
        "--promote",
        action="store_true",
        help="replace the baseline with the fresh results and exit",
    )
    args = ap.parse_args(argv)

    fresh = load_cases(args.fresh)
    if args.promote:
        if not gated(fresh):
            print(f"refusing to promote {args.fresh}: no gated cases in it")
            return 1
        shutil.copyfile(args.fresh, args.baseline)
        print(f"promoted {args.fresh} -> {args.baseline} "
              f"({len(fresh)} cases, gate armed)")
        return 0

    base = load_cases(args.baseline)
    provisional = PROVISIONAL_MARKER in base or all(
        ips is None for ips in gated(base).values()
    )

    base_gated = {n: v for n, v in gated(base).items() if n != PROVISIONAL_MARKER}
    fresh_gated = gated(fresh)
    failures: list[str] = []
    rows: list[str] = []
    for name in sorted(set(base_gated) | set(fresh_gated)):
        b, f = base_gated.get(name), fresh_gated.get(name)
        if name not in fresh_gated:
            failures.append(f"{name}: gated case missing from fresh run")
            continue
        if name not in base_gated:
            rows.append(f"  {name}: new case (no baseline), {f:.1f} items/s")
            continue
        if b is None or f is None:
            rows.append(f"  {name}: no throughput to compare")
            continue
        delta = (f - b) / b if b > 0 else 0.0
        verdict = "OK"
        if delta < -args.tolerance:
            verdict = "REGRESSED"
            failures.append(
                f"{name}: {f:.1f} items/s vs baseline {b:.1f} "
                f"({delta:+.1%} < -{args.tolerance:.0%})"
            )
        rows.append(f"  {name}: {b:.1f} -> {f:.1f} items/s ({delta:+.1%}) {verdict}")

    # SIMD speedup floor, measured entirely within the fresh run.
    ratio_failures: list[str] = []
    scalar_ips, simd_ips = fresh.get(KERNEL_SCALAR), fresh.get(KERNEL_SIMD)
    if isinstance(scalar_ips, (int, float)) and isinstance(simd_ips, (int, float)) \
            and scalar_ips > 0:
        speedup = simd_ips / scalar_ips
        verdict = "OK" if speedup >= MIN_KERNEL_SPEEDUP else "TOO SLOW"
        rows.append(
            f"  kernels 32k speedup: simd {speedup:.2f}x scalar "
            f"(floor {MIN_KERNEL_SPEEDUP:.1f}x) {verdict}"
        )
        if speedup < MIN_KERNEL_SPEEDUP:
            ratio_failures.append(
                f"simd kernel only {speedup:.2f}x scalar on the 32k group "
                f"(floor {MIN_KERNEL_SPEEDUP:.1f}x): "
                f"{simd_ips:.1f} vs {scalar_ips:.1f} items/s"
            )
    elif KERNEL_SCALAR in fresh or KERNEL_SIMD in fresh:
        rows.append("  kernels 32k speedup: pair not measured in fresh run (skipped)")

    # Prefix-cache hit/miss floor, also measured within the fresh run.
    hit_ips, miss_ips = fresh.get(CACHE_HIT), fresh.get(CACHE_MISS)
    if isinstance(hit_ips, (int, float)) and isinstance(miss_ips, (int, float)) \
            and miss_ips > 0:
        speedup = hit_ips / miss_ips
        verdict = "OK" if speedup >= MIN_CACHE_SPEEDUP else "TOO SLOW"
        rows.append(
            f"  kvcache hit/miss: {speedup:.2f}x "
            f"(floor {MIN_CACHE_SPEEDUP:.1f}x) {verdict}"
        )
        if speedup < MIN_CACHE_SPEEDUP:
            ratio_failures.append(
                f"prefix-cache hit only {speedup:.2f}x miss "
                f"(floor {MIN_CACHE_SPEEDUP:.1f}x): "
                f"{hit_ips:.1f} vs {miss_ips:.1f} items/s"
            )
    elif CACHE_HIT in fresh or CACHE_MISS in fresh:
        rows.append("  kvcache hit/miss: pair not measured in fresh run (skipped)")

    # Flight-recorder overhead ceiling, also fresh-run-only (DESIGN.md
    # §14): tracing-on throughput within MAX_TRACE_OVERHEAD of tracing-off
    # on the shared-pool hot path.
    off_ips, on_ips = fresh.get(TRACE_OFF), fresh.get(TRACE_ON)
    if isinstance(off_ips, (int, float)) and isinstance(on_ips, (int, float)) \
            and on_ips > 0:
        overhead = off_ips / on_ips - 1.0
        verdict = "OK" if overhead <= MAX_TRACE_OVERHEAD else "TOO SLOW"
        rows.append(
            f"  trace on vs off: {overhead:+.1%} overhead "
            f"(ceiling {MAX_TRACE_OVERHEAD:.0%}) {verdict}"
        )
        if overhead > MAX_TRACE_OVERHEAD:
            ratio_failures.append(
                f"tracing-on overhead {overhead:.1%} exceeds the "
                f"{MAX_TRACE_OVERHEAD:.0%} ceiling: "
                f"{on_ips:.1f} vs {off_ips:.1f} items/s"
            )
    elif TRACE_OFF in fresh or TRACE_ON in fresh:
        rows.append("  trace on vs off: pair not measured in fresh run (skipped)")

    print(f"bench-check: {len(base_gated) or len(fresh_gated)} gated case(s), "
          f"tolerance {args.tolerance:.0%}")
    for row in rows:
        print(row)

    # A provisional baseline waives only the baseline comparison; the
    # fresh-run-only floors above always apply.
    if provisional:
        if failures:
            print(f"baseline is PROVISIONAL: waiving {len(failures)} "
                  "baseline-comparison failure(s)")
        failures = []
    # Report EVERY violated floor in one run — a ratio-floor failure must
    # not mask baseline regressions, nor the other way around.
    all_failures = failures + ratio_failures
    if all_failures:
        print(f"bench-check FAILED ({len(all_failures)} violated floor(s)):")
        for f in all_failures:
            print(f"  {f}")
        return 1
    if provisional:
        print(
            "baseline is PROVISIONAL (no measured numbers committed): gate "
            "passes unconditionally.\nPromote real numbers with: "
            f"python python/bench_check.py {args.baseline} {args.fresh} --promote"
        )
        return 0
    print("bench-check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
