"""L1 Pallas kernel: decode-step attention with GQA.

One query token per sequence against the KV cache. The grid iterates over
the batch; each step keeps one sequence's [T, KVH, Dh] cache panel in VMEM
and computes a masked softmax-attention for its H query heads. T is blocked
implicitly by the cache length (small for the e2e model); on a real TPU the
T axis would be further tiled with a second grid dimension and the same
online-softmax rescaling used in `lm_head.py`.

interpret=True for CPU execution (see lm_head.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref):
    # Blocks: q [1, H, Dh], k/v [1, T, KVH, Dh], len [1], o [1, H, Dh].
    q = q_ref[0]  # [H, Dh]
    k = k_ref[0]  # [T, KVH, Dh]
    v = v_ref[0]
    n = len_ref[0]

    h, dh = q.shape
    t, kvh, _ = k.shape
    group = h // kvh
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    qg = q.reshape(kvh, group, dh)
    # [KVH, group, T]
    scores = jnp.einsum("kgd,tkd->kgt", qg, k, preferred_element_type=jnp.float32)
    scores = scores * scale
    mask = jnp.arange(t)[None, None, :] < n
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("kgt,tkd->kgd", p, v, preferred_element_type=jnp.float32)
    o_ref[0] = out.reshape(h, dh)


@jax.jit
def decode_attention(q, k, v, lengths):
    """Decode attention: q [B, H, Dh], cache k/v [B, T, KVH, Dh],
    lengths [B] (valid prefix incl. the current token). Returns [B, H, Dh]."""
    b, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0, "H must be a multiple of KVH (GQA)"
    return pl.pallas_call(
        _decode_attn_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, kvh, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, t, kvh, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        interpret=True,
    )(q, k, v, lengths)


@functools.lru_cache(maxsize=None)
def vmem_bytes(t, kvh, dh, h):
    """Per-grid-step VMEM estimate (f32) for DESIGN.md §Perf."""
    return 4 * (h * dh + 2 * t * kvh * dh + h * dh + h // max(kvh, 1) * t * kvh)
