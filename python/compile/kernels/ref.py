"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: pytest/hypothesis sweep shapes and
compare the Pallas kernels (run in interpret mode) against these with
assert_allclose. They are also small enough to read as the spec.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def ref_lm_head(x, w, bias, tau, hot_mask):
    """Fused LM head + SHVS precompute (paper Eq. 6-7), reference.

    Args:
      x: [B, D] final hidden states.
      w: [D, V] output projection.
      bias: [V] additive per-token bias.
      tau: [B] per-sequence temperature (>0; engine sends 1.0 for greedy).
      hot_mask: [V] float 0/1, 1 = hot-set member.

    Returns:
      logits: [B, V]
      stats:  [B, 4] = (z_max, s_hot, s_tail, tail_max_w) where
        w_v = exp((z_v - z_max)/tau), s_hot = sum_{hot} w_v,
        s_tail = sum_{tail} w_v, tail_max_w = max_{tail} w_v.
    """
    logits = x @ w + bias[None, :]  # [B, V]
    z_max = jnp.max(logits, axis=1)  # [B]
    wgt = jnp.exp((logits - z_max[:, None]) / tau[:, None])  # [B, V]
    hot = hot_mask[None, :]
    s_hot = jnp.sum(wgt * hot, axis=1)
    s_tail = jnp.sum(wgt * (1.0 - hot), axis=1)
    tail_max = jnp.max(jnp.where(hot > 0, 0.0, wgt), axis=1)
    stats = jnp.stack([z_max, s_hot, s_tail, tail_max], axis=1)
    return logits, stats


def ref_decode_attention(q, k, v, lengths):
    """Single-step (decode) attention with GQA, reference.

    Args:
      q: [B, H, Dh] this step's queries.
      k: [B, T, KVH, Dh] key cache (only the first lengths[b] rows valid).
      v: [B, T, KVH, Dh] value cache.
      lengths: [B] int32, number of valid cache positions (incl. this step).

    Returns:
      out: [B, H, Dh]
    """
    b, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    qg = q.reshape(b, kvh, group, dh)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k) * scale
    mask = jnp.arange(t)[None, :] < lengths[:, None]  # [B, T]
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v)
    return out.reshape(b, h, dh)
