"""L1 Pallas kernel: fused LM-head projection + SHVS precompute.

This is the paper's "w_{b,v} can be pre-computed on GPUs when writing
logits" (§5.3) re-thought for TPU:

- The hidden→vocab GEMM is tiled along the vocabulary axis with a
  `BlockSpec` grid, streaming [D, BV] weight panels through VMEM while the
  [B, D] activations stay resident — MXU-shaped blocks instead of CUDA
  threadblocks.
- The SHVS statistics (running max `z_max`, hot/tail weight sums, tail max
  weight; Eq. 6-7) are fused into the same grid pass with an *online
  softmax* rescaling (flash-attention style), so logits never make a second
  HBM round trip.

Run with interpret=True on CPU (real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute); the BlockSpec structure is
what carries over to real hardware. See DESIGN.md §Hardware-Adaptation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _lm_head_kernel(x_ref, w_ref, bias_ref, tau_ref, hot_ref, logits_ref, stats_ref):
    """One vocab-block step: GEMM + online stats update.

    Grid: (V // block_v,). Revisited output `stats_ref` accumulates across
    steps (sequential TPU grid semantics; interpret mode matches).
    """
    j = pl.program_id(0)

    # MXU block: [B, D] @ [D, BV] -> [B, BV], f32 accumulate, fused bias.
    logits = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    logits = logits + bias_ref[...][None, :]
    logits_ref[...] = logits

    tau = tau_ref[...]  # [B]
    hot = hot_ref[...][None, :]  # [1, BV]

    # First-block init by *select*, not a predicated write: the accumulator
    # is written exactly once per grid step and never read before being
    # masked, so correctness is independent of the output buffer's initial
    # contents (XLA is free to leave revisited buffers uninitialized).
    is_first = j == 0
    m_old = jnp.where(is_first, NEG_INF, stats_ref[:, 0])
    s_hot = jnp.where(is_first, 0.0, stats_ref[:, 1])
    s_tail = jnp.where(is_first, 0.0, stats_ref[:, 2])
    t_max = jnp.where(is_first, 0.0, stats_ref[:, 3])

    blk_max = jnp.max(logits, axis=1)
    m_new = jnp.maximum(m_old, blk_max)
    # Rescale previous sums to the new max (online softmax).
    scale = jnp.exp((m_old - m_new) / tau)
    w = jnp.exp((logits - m_new[:, None]) / tau[:, None])  # [B, BV]
    s_hot = s_hot * scale + jnp.sum(w * hot, axis=1)
    s_tail = s_tail * scale + jnp.sum(w * (1.0 - hot), axis=1)
    t_max = jnp.maximum(t_max * scale, jnp.max(jnp.where(hot > 0, 0.0, w), axis=1))

    stats_ref[...] = jnp.stack([m_new, s_hot, s_tail, t_max], axis=1)


@functools.partial(jax.jit, static_argnames=("block_v",))
def lm_head(x, w, bias, tau, hot_mask, *, block_v=2048):
    """Fused LM head: logits [B, V] + SHVS stats [B, 4].

    stats[:, 0] = z_max, stats[:, 1] = S_hot, stats[:, 2] = S_tail,
    stats[:, 3] = max tail weight — exactly `decision::shvs::Precompute`.
    """
    b, d = x.shape
    d2, v = w.shape
    assert d == d2, f"hidden mismatch {d} vs {d2}"
    assert v % block_v == 0 or block_v >= v, "block_v must tile V"
    bv = min(block_v, v)
    grid = (v // bv,)
    return pl.pallas_call(
        _lm_head_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda j: (0, 0)),  # activations: VMEM-resident
            pl.BlockSpec((d, bv), lambda j: (0, j)),  # weight panel streams
            pl.BlockSpec((bv,), lambda j: (j,)),  # per-token bias
            pl.BlockSpec((b,), lambda j: (0,)),
            pl.BlockSpec((bv,), lambda j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((b, bv), lambda j: (0, j)),
            pl.BlockSpec((b, 4), lambda j: (0, 0)),  # revisited accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, v), jnp.float32),
            jax.ShapeDtypeStruct((b, 4), jnp.float32),
        ],
        interpret=True,
    )(x, w, bias, tau, hot_mask)


def vmem_bytes(b, d, block_v):
    """Estimated VMEM working set of one grid step (f32): activations +
    weight panel + logits block + stats. Used by the DESIGN.md §Perf roofline
    notes, not at runtime."""
    return 4 * (b * d + d * block_v + b * block_v + b * 4)


def mxu_utilization_estimate(b, d, block_v, mxu=128):
    """Fraction of MXU lanes fed by the [B, D]x[D, BV] block shape: the MXU
    is a 128x128 systolic array; blocks smaller than 128 in each GEMM dim
    leave lanes idle."""
    eff_m = min(b, mxu) / mxu
    eff_k = min(d, mxu) / mxu
    eff_n = min(block_v, mxu) / mxu
    return eff_m * eff_k * eff_n
