"""AOT compile path: JAX model -> HLO text + weights + manifest.

Run once by `make artifacts` (incremental: skips models whose inputs are
unchanged). Python never runs on the request path — the Rust runtime loads
`artifacts/<model>/decode.hlo.txt` through PJRT and uploads the .npy
weights as device buffers.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def source_fingerprint() -> str:
    """Hash of the compile-path sources — the incremental-build key."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(root, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def build_model(cfg: model_lib.ModelConfig, out_dir: str) -> dict:
    """Lower one model config; write HLO + weights; return its manifest."""
    mdir = os.path.join(out_dir, cfg.name)
    wdir = os.path.join(mdir, "weights")
    os.makedirs(wdir, exist_ok=True)

    # --- weights ---
    weights = model_lib.init_weights(cfg)
    names = model_lib.weight_names(cfg)
    for name in names:
        np.save(os.path.join(wdir, f"{name}.npy"), weights[name])

    # --- HLO ---
    f = model_lib.decode_step_flat(cfg)
    lowered = jax.jit(f).lower(*model_lib.example_args(cfg))
    hlo = to_hlo_text(lowered)
    hlo_path = os.path.join(mdir, "decode.hlo.txt")
    with open(hlo_path, "w") as fh:
        fh.write(hlo)

    shapes = model_lib.weight_shapes(cfg)
    kv_shape = [cfg.layers, cfg.batch, cfg.max_seq, cfg.kv_heads, cfg.head_dim]
    return {
        "name": cfg.name,
        "hlo": f"{cfg.name}/decode.hlo.txt",
        "batch": cfg.batch,
        "vocab": cfg.vocab,
        "layers": cfg.layers,
        "hidden": cfg.hidden,
        "heads": cfg.heads,
        "kv_heads": cfg.kv_heads,
        "head_dim": cfg.head_dim,
        "ffn_hidden": cfg.ffn_hidden,
        "max_seq": cfg.max_seq,
        "kv_shape": kv_shape,
        "weights": [
            {
                "name": n,
                "file": f"{cfg.name}/weights/{n}.npy",
                "shape": list(shapes[n]),
            }
            for n in names
        ],
        # Flat argument order after the weights:
        "extra_args": ["ids", "positions", "kv_k", "kv_v", "tau", "hot_mask"],
        # Tuple output order:
        "outputs": ["logits", "stats", "kv_k", "kv_v"],
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models",
        default="micro-test,tiny-30m",
        help="comma-separated model names (see model.CONFIGS)",
    )
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    fingerprint = source_fingerprint()

    if not args.force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                existing = json.load(f)
            if existing.get("fingerprint") == fingerprint and all(
                os.path.exists(os.path.join(out_dir, m["hlo"]))
                for m in existing.get("models", [])
            ):
                print(f"artifacts up to date (fingerprint {fingerprint})")
                return 0
        except (json.JSONDecodeError, KeyError):
            pass

    models = []
    for name in args.models.split(","):
        cfg = model_lib.CONFIGS[name.strip()]
        print(f"lowering {cfg.name} (V={cfg.vocab}, B={cfg.batch}) ...")
        models.append(build_model(cfg, out_dir))

    manifest = {"fingerprint": fingerprint, "models": models}
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
