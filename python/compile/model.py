"""L2: decode-step transformer producing logits + SHVS precompute.

A small Llama-style decoder (RMSNorm, RoPE, GQA attention, SiLU-gated MLP)
whose single-token decode step is AOT-lowered to HLO text and executed from
the Rust runtime via PJRT. The attention and LM-head hot spots call the L1
Pallas kernels, so they lower into the same HLO module.

Weights are generated deterministically (seeded) at AOT time and shipped as
.npy files the Rust side uploads once as device buffers; the HLO takes them
as leading arguments so nothing heavyweight is baked into the module text.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.attention import decode_attention
from .kernels.lm_head import lm_head


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Mirrors `rust/src/config/model.rs` for the AOT-compiled models."""

    name: str
    layers: int
    hidden: int
    heads: int
    kv_heads: int
    ffn_hidden: int
    vocab: int
    max_seq: int  # KV-cache capacity T (static in the HLO)
    batch: int  # microbatch size B (static in the HLO)
    seed: int = 0x51113
    zipf_s: float = 1.05  # Zipf exponent of the LM-head rank bias (§5.3)

    @property
    def head_dim(self):
        return self.hidden // self.heads


TINY_E2E = ModelConfig(
    name="tiny-30m",
    layers=4,
    hidden=256,
    heads=8,
    kv_heads=8,
    ffn_hidden=1024,
    vocab=32_000,
    max_seq=256,
    batch=8,
)

MICRO_TEST = ModelConfig(
    name="micro-test",
    layers=2,
    hidden=64,
    heads=4,
    kv_heads=4,
    ffn_hidden=128,
    vocab=1_000,
    max_seq=64,
    batch=4,
)

CONFIGS = {c.name: c for c in (TINY_E2E, MICRO_TEST)}


def weight_names(cfg: ModelConfig):
    """Fixed argument order of the weight tensors (manifest + HLO args)."""
    names = ["embedding"]
    for l in range(cfg.layers):
        names += [
            f"layer{l}.ln1",
            f"layer{l}.wqkv",
            f"layer{l}.wo",
            f"layer{l}.ln2",
            f"layer{l}.w_gate",
            f"layer{l}.w_up",
            f"layer{l}.w_down",
        ]
    names += ["ln_final", "lm_head", "lm_bias"]
    return names


def weight_shapes(cfg: ModelConfig):
    d, h, kvh, dh, f, v = (
        cfg.hidden,
        cfg.heads,
        cfg.kv_heads,
        cfg.head_dim,
        cfg.ffn_hidden,
        cfg.vocab,
    )
    qkv_out = (h + 2 * kvh) * dh
    shapes = {"embedding": (v, d)}
    for l in range(cfg.layers):
        shapes[f"layer{l}.ln1"] = (d,)
        shapes[f"layer{l}.wqkv"] = (d, qkv_out)
        shapes[f"layer{l}.wo"] = (h * dh, d)
        shapes[f"layer{l}.ln2"] = (d,)
        shapes[f"layer{l}.w_gate"] = (d, f)
        shapes[f"layer{l}.w_up"] = (d, f)
        shapes[f"layer{l}.w_down"] = (f, d)
    shapes["ln_final"] = (d,)
    shapes["lm_head"] = (d, v)
    shapes["lm_bias"] = (v,)
    return shapes


def init_weights(cfg: ModelConfig):
    """Deterministic synthetic weights (truncated-normal-ish scaling).

    The decision plane's behaviour depends on the logits *distribution*,
    not on trained weight values (DESIGN.md §2); scaled Gaussian weights
    give well-conditioned, Zipf-ish-after-softmax logits.
    """
    rng = np.random.default_rng(cfg.seed)
    shapes = weight_shapes(cfg)
    out = {}
    for name in weight_names(cfg):
        shape = shapes[name]
        if name.endswith(("ln1", "ln2", "ln_final")):
            out[name] = np.ones(shape, np.float32)
        elif name == "lm_bias":
            # Zipf-shaped rank bias: softmax(-s ln(rank)) IS a Zipf(s)
            # distribution — gives the Zipf-like next-token mass the paper
            # observes in real traces (SHVS premise, §5.3). Per-step hidden
            # states then modulate it with ~N(0,1) logit noise.
            v = cfg.vocab
            out[name] = (-cfg.zipf_s * np.log(np.arange(v) + 2.0)).astype(np.float32)
        else:
            fan_in = shape[0]
            std = (1.0 / fan_in) ** 0.5
            out[name] = rng.normal(0.0, std, shape).astype(np.float32)
    return out


def pick_block_v(vocab, target=2048):
    """Largest divisor of `vocab` not exceeding `target` (grid must tile V)."""
    best = 1
    d = 1
    while d * d <= vocab:
        if vocab % d == 0:
            for cand in (d, vocab // d):
                if cand <= target and cand > best:
                    best = cand
        d += 1
    return best


def rms_norm(x, g, eps=1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope(x, positions):
    """Rotary embedding: x [B, n, Dh], positions [B]."""
    b, n, dh = x.shape
    half = dh // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [B, half]
    cos = jnp.cos(angles)[:, None, :]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def decode_step(weights, ids, positions, kv_k, kv_v, tau, hot_mask, cfg: ModelConfig):
    """One decode iteration.

    Args:
      weights: dict name -> array (see weight_names).
      ids: [B] int32 current tokens.
      positions: [B] int32 positions of `ids` in their sequences.
      kv_k, kv_v: [L, B, T, KVH, Dh] caches.
      tau: [B] temperatures for the SHVS precompute.
      hot_mask: [V] 0/1 hot-set membership.

    Returns:
      (logits [B, V], stats [B, 4], new_kv_k, new_kv_v)
    """
    d, h, kvh, dh = cfg.hidden, cfg.heads, cfg.kv_heads, cfg.head_dim
    t = cfg.max_seq

    x = weights["embedding"][ids]  # [B, D]
    onehot_t = (jnp.arange(t)[None, :] == positions[:, None]).astype(jnp.float32)

    new_k_layers = []
    new_v_layers = []
    for l in range(cfg.layers):
        hln = rms_norm(x, weights[f"layer{l}.ln1"])
        qkv = hln @ weights[f"layer{l}.wqkv"]  # [B, (H+2KVH)*Dh]
        q, k_new, v_new = jnp.split(qkv, [h * dh, (h + kvh) * dh], axis=1)
        q = rope(q.reshape(-1, h, dh), positions)
        k_new = rope(k_new.reshape(-1, kvh, dh), positions)
        v_new = v_new.reshape(-1, kvh, dh)

        # Write this step's K/V at each sequence's position (one-hot blend).
        oh = onehot_t[:, :, None, None]  # [B, T, 1, 1]
        k_cache = kv_k[l] * (1.0 - oh) + k_new[:, None, :, :] * oh
        v_cache = kv_v[l] * (1.0 - oh) + v_new[:, None, :, :] * oh
        new_k_layers.append(k_cache)
        new_v_layers.append(v_cache)

        attn = decode_attention(q, k_cache, v_cache, positions + 1)  # [B, H, Dh]
        x = x + attn.reshape(-1, h * dh) @ weights[f"layer{l}.wo"]

        hln2 = rms_norm(x, weights[f"layer{l}.ln2"])
        gate = jax.nn.silu(hln2 @ weights[f"layer{l}.w_gate"])
        up = hln2 @ weights[f"layer{l}.w_up"]
        x = x + (gate * up) @ weights[f"layer{l}.w_down"]

    x = rms_norm(x, weights["ln_final"])
    logits, stats = lm_head(x, weights["lm_head"], weights["lm_bias"], tau,
                            hot_mask, block_v=pick_block_v(cfg.vocab))
    return logits, stats, jnp.stack(new_k_layers), jnp.stack(new_v_layers)


def decode_step_flat(cfg: ModelConfig):
    """Return a flat-arg function suitable for jax.jit().lower():
    f(w_0..w_n, ids, positions, kv_k, kv_v, tau, hot_mask) -> tuple."""
    names = weight_names(cfg)

    def f(*args):
        nw = len(names)
        weights = dict(zip(names, args[:nw]))
        ids, positions, kv_k, kv_v, tau, hot_mask = args[nw:]
        return decode_step(weights, ids, positions, kv_k, kv_v, tau, hot_mask, cfg)

    return f


def example_args(cfg: ModelConfig):
    """ShapeDtypeStructs for lowering, in flat-arg order."""
    shapes = weight_shapes(cfg)
    args = [
        jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in weight_names(cfg)
    ]
    b, t = cfg.batch, cfg.max_seq
    kv = (cfg.layers, b, t, cfg.kv_heads, cfg.head_dim)
    args += [
        jax.ShapeDtypeStruct((b,), jnp.int32),  # ids
        jax.ShapeDtypeStruct((b,), jnp.int32),  # positions
        jax.ShapeDtypeStruct(kv, jnp.float32),  # kv_k
        jax.ShapeDtypeStruct(kv, jnp.float32),  # kv_v
        jax.ShapeDtypeStruct((b,), jnp.float32),  # tau
        jax.ShapeDtypeStruct((cfg.vocab,), jnp.float32),  # hot_mask
    ]
    return args
