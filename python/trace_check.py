#!/usr/bin/env python3
"""Validate a flight-recorder capture (DESIGN.md §14).

``make trace-smoke`` runs a 2-replica shared-pool chaos serve through
``serve_e2e --trace`` and feeds the capture here. Checks:

1. **Schema** — the Chrome Trace Event Format object form: a top-level
   ``traceEvents`` array whose entries carry ``name``/``ph``/``pid``/
   ``tid`` (plus ``ts`` for real events, ``dur`` for ``X``), with event
   names drawn from the declared taxonomy (``rust/src/trace/mod.rs``
   ``Kind::name`` — keep ``KNOWN_EVENTS`` in sync).
2. **Monotonic timestamps** — events are globally sorted by ``ts`` (the
   exporter merges per-thread rings into one ordered stream), and no
   timestamp is negative.
3. **Balanced B/E** — per (pid, tid) lane, every ``E`` closes the most
   recent open ``B`` of the same name (LIFO), and no span stays open at
   the end. When the capture reports ring overwrites
   (``otherData.dropped_events`` > 0) a span's ``B`` may have been
   dropped while its ``E`` survived, so unmatched events are tolerated
   *only* in that case.
4. **Required events** — every event name in ``--require`` appears at
   least once (e.g. the chaos smoke demands steal/respawn/COW-fork/
   evict/route coverage).

Exit 0 on a valid capture, 1 otherwise, printing every violation (capped
per category). Stdlib only — no third-party imports.
"""

from __future__ import annotations

import argparse
import json
import sys

# The declared taxonomy — mirrors Kind::name in rust/src/trace/mod.rs.
KNOWN_EVENTS = {
    "sched.admit", "sched.resume", "sched.preempt", "sched.chunk",
    "engine.plan", "engine.forward", "engine.commit", "engine.collect_wait",
    "svc.submit", "svc.decide", "svc.collect", "svc.steal",
    "svc.claim_release", "svc.respawn",
    "slot.recover",
    "kv.hit", "kv.miss", "kv.cow_fork", "kv.evict",
    "route.decision", "route.requeue",
    "log",
}
# Metadata records Perfetto uses for lane names, not timeline events.
METADATA_EVENTS = {"process_name", "thread_name"}
PHASES = {"B", "E", "X", "i", "M"}
MAX_REPORTED = 10  # per category; the summary still counts everything


def is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def check(path: str, require: list[str]) -> int:
    with open(path, encoding="utf-8") as f:
        capture = json.load(f)

    errors: dict[str, list[str]] = {}

    def err(category: str, msg: str) -> None:
        errors.setdefault(category, []).append(msg)

    if not isinstance(capture, dict) or not isinstance(
        capture.get("traceEvents"), list
    ):
        print(f"{path}: not a Chrome-trace object (missing traceEvents array)")
        return 1
    events = capture["traceEvents"]
    dropped = 0
    other = capture.get("otherData")
    if isinstance(other, dict) and is_num(other.get("dropped_events")):
        dropped = int(other["dropped_events"])

    # --- schema ---
    timeline = []  # non-metadata events, in file order
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            err("schema", f"{where}: not an object")
            continue
        name, ph = ev.get("name"), ev.get("ph")
        if not isinstance(name, str) or ph not in PHASES:
            err("schema", f"{where}: bad name/ph: {name!r}/{ph!r}")
            continue
        if not is_num(ev.get("pid")) or not is_num(ev.get("tid")):
            err("schema", f"{where} ({name}): pid/tid must be numbers")
            continue
        if ph == "M":
            if name not in METADATA_EVENTS:
                err("schema", f"{where}: unknown metadata record {name!r}")
            continue
        if name not in KNOWN_EVENTS:
            err("schema", f"{where}: undeclared event name {name!r}")
            continue
        if not is_num(ev.get("ts")) or ev["ts"] < 0:
            err("schema", f"{where} ({name}): ts must be a non-negative number")
            continue
        if ph == "X" and (not is_num(ev.get("dur")) or ev["dur"] < 0):
            err("schema", f"{where} ({name}): X event needs a non-negative dur")
            continue
        timeline.append(ev)

    # --- monotonic timestamps (global: the exporter sorts the merge) ---
    last_ts = 0.0
    for ev in timeline:
        if ev["ts"] < last_ts:
            err(
                "monotonic",
                f"{ev['name']} at ts={ev['ts']} after ts={last_ts} "
                f"(pid {ev['pid']}, tid {ev['tid']})",
            )
        last_ts = max(last_ts, ev["ts"])

    # --- balanced B/E per lane, LIFO by name ---
    stacks: dict[tuple, list[str]] = {}
    unmatched = 0
    for ev in timeline:
        lane = (ev["pid"], ev["tid"])
        stack = stacks.setdefault(lane, [])
        if ev["ph"] == "B":
            stack.append(ev["name"])
        elif ev["ph"] == "E":
            if stack and stack[-1] == ev["name"]:
                stack.pop()
            elif dropped > 0:
                # the ring overwrote this E's B (or an ancestor's) — with
                # overwrites on record, tolerate rather than flag
                unmatched += 1
            elif not stack:
                err("balance", f"lane {lane}: E {ev['name']!r} with no open B")
            else:
                err(
                    "balance",
                    f"lane {lane}: E {ev['name']!r} closes open B {stack[-1]!r} "
                    "(not LIFO)",
                )
    for lane, stack in stacks.items():
        if stack and dropped == 0:
            err("balance", f"lane {lane}: {len(stack)} span(s) left open: {stack}")

    # --- required event coverage ---
    seen = {ev["name"] for ev in timeline}
    for name in require:
        if name not in KNOWN_EVENTS:
            err("require", f"--require {name!r} is not a declared event name")
        elif name not in seen:
            err("require", f"required event {name!r} absent from the capture")

    counts = {}
    for ev in timeline:
        counts[ev["name"]] = counts.get(ev["name"], 0) + 1
    print(
        f"{path}: {len(timeline)} events across {len(stacks)} lane(s), "
        f"{len(counts)} distinct kind(s), {dropped} dropped to ring overwrite"
    )
    for name in sorted(counts):
        print(f"  {name}: {counts[name]}")
    if unmatched and dropped > 0:
        print(
            f"  note: {unmatched} unmatched E event(s) tolerated "
            "(ring overwrote their B)"
        )

    if errors:
        total = sum(len(v) for v in errors.values())
        print(f"trace-check FAILED: {total} violation(s)")
        for category, msgs in errors.items():
            for msg in msgs[:MAX_REPORTED]:
                print(f"  [{category}] {msg}")
            if len(msgs) > MAX_REPORTED:
                print(f"  [{category}] ... and {len(msgs) - MAX_REPORTED} more")
        return 1
    print("trace-check passed")
    return 0


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("capture", help="Chrome-trace JSON written by --trace")
    ap.add_argument(
        "--require",
        default="",
        help="comma-separated event names that must appear (e.g. "
        "svc.steal,svc.respawn,kv.cow_fork,kv.evict,route.decision)",
    )
    args = ap.parse_args(argv)
    require = [n.strip() for n in args.require.split(",") if n.strip()]
    return check(args.capture, require)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
