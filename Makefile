# simple-serve build entrypoints. `make artifacts` is the one the code
# cites: it lowers the L2 JAX model (with the L1 Pallas kernel inside) to
# HLO text + npy weights + manifest under artifacts/, incrementally.

.PHONY: artifacts artifacts-force build test figures cluster-smoke chaos-smoke cache-smoke trace-smoke bench bench-check lint loom miri tsan ci

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

artifacts-force:
	cd python && python -m compile.aot --out-dir ../artifacts --force

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

figures: build
	cargo run --release -- figures

# The cluster experiment at smoke effort on the synthetic plane (no
# artifacts needed): replicas × routing policy × traffic; the experiment
# asserts every fleet digest equals the single-engine baseline, so a
# routing bug fails this target loudly.
cluster-smoke: build
	cargo run --release -- figures --experiments cluster

# The chaos experiment at smoke effort (DESIGN.md §10): injected sampler
# kills (including the legacy poison@ syntax, now a clean worker kill) /
# replica kills; the experiment asserts every fleet digest equals the
# fault-free baseline, so a recovery bug fails this target loudly.
chaos-smoke: build
	cargo run --release -- figures --experiments chaos

# The prefixcache experiment at smoke effort (DESIGN.md §13): radix KV
# reuse over conversation trees, single engine and cluster, reuse on vs
# off. The experiment asserts the hard bars itself — ≥30% prefill-token
# reduction and bit-identical stream digests under caching, eviction,
# preemption, and prefix-cache routing — so a reuse bug fails loudly.
cache-smoke: build
	cargo run --release -- figures --experiments prefixcache

# Flight-recorder smoke (DESIGN.md §14): a 2-replica shared-pool chaos
# serve over a conversation trace with the prefix cache squeezed, traced
# end to end. The validator checks the capture's schema / timestamp
# order / B-E balance and demands steal, respawn, COW-fork, evict, and
# route events — the full decision-plane story in one timeline. The
# capture loads directly in ui.perfetto.dev or chrome://tracing. Needs
# artifacts (serve_e2e runs the AOT model).
trace-smoke: build artifacts
	cargo run --release --example serve_e2e -- --quick --conv --prefix_cache \
		--kv_blocks 32 --replicas 2 --shared_samplers \
		--chaos "sampler:0@4,replica:1@6" \
		--trace results/trace_smoke.json \
		--metrics_out results/metrics_smoke.prom
	python python/trace_check.py results/trace_smoke.json \
		--require svc.steal,svc.respawn,kv.cow_fork,kv.evict,route.decision

# Decision-plane microbenchmarks (quick profile), including the
# chaos/recovery_pause group, with machine-readable output — CI uploads
# BENCH_decision.json so throughput/P95 are tracked across PRs.
bench: build
	cargo bench --bench decision_micro -- --quick --json BENCH_decision.json

# Perf-regression gate (DESIGN.md §11–§12, §14): re-run the
# microbenchmarks into a scratch file and compare the gated groups
# (cluster shared-pool, the fused dense-kernel pair, the kvcache
# hit/miss pair, and the trace on/off pair) against the committed
# BENCH_decision.json — a >15% items/s drop fails, the kernel pair must
# hold simd ≥ 1.5× scalar on the 32k-vocab group, and tracing-on must
# stay within 10% of tracing-off. Must run BEFORE
# `bench`, which overwrites the committed baseline in place. A
# provisional (unmeasured) baseline warns and passes the baseline
# comparison; promote real numbers with `python python/bench_check.py
# BENCH_decision.json BENCH_decision.fresh.json --promote`.
bench-check: build
	cargo bench --bench decision_micro -- --quick --json BENCH_decision.fresh.json
	python python/bench_check.py BENCH_decision.json BENCH_decision.fresh.json

# Concurrency lint (DESIGN.md §15): source-level, no Rust toolchain
# needed. Every `unsafe` needs a `// SAFETY:`, every mutating Relaxed
# atomic op needs an `// ordering:`, and hot-path files (decision
# service/slots, ringbuf) may not take locks outside tests without a
# documented `cold` waiver. Zero violations is a CI gate.
lint:
	python python/lint_concurrency.py rust/src --json results/lint_concurrency.json

# Loom model checking of the lock-free decision plane (DESIGN.md §15):
# exhaustively explores thread interleavings (bounded at 3 preemptions)
# of the MPMC ring, slot table, SeqRec, and flight ring — including
# regression models for the PR 6 dead-claim-release race and the PR 9
# flight-ring torn-record race. Requires the cfg-gated dependency
#   [target.'cfg(loom)'.dependencies] loom = "0.7"
# in Cargo.toml; without --cfg loom the models compile to an empty test
# crate and normal builds never see loom.
loom:
	RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
		cargo test --release --test loom_models

# Miri (nightly): UB interpreter over the ringbuf + slot-table unit
# tests — catches stacked-borrows/provenance bugs loom cannot see.
# Tests scale themselves down under cfg(miri). Slow; nightly CI lane.
miri:
	MIRIFLAGS="-Zmiri-disable-isolation" \
		cargo +nightly miri test -q ringbuf:: decision::slots::

# ThreadSanitizer (nightly): runs the lockfree_service integration
# suite — real OS threads, real weak-memory reorderings on the actual
# codegen. Complements loom (model) and Miri (single-interleaving UB).
tsan:
	RUSTFLAGS="-Zsanitizer=thread" \
		cargo +nightly test --release --test lockfree_service \
		-Zbuild-std --target x86_64-unknown-linux-gnu

# What .github/workflows/ci.yml runs: the concurrency lint, fmt +
# clippy gates, release build + tests, the cluster/chaos/cache/trace
# smokes, the bench JSON, python kernel/model tests (hypothesis
# optional — shim fallback). Loom/Miri/TSan run as separate CI lanes
# (`make loom|miri|tsan`), not here — loom explores interleavings for
# minutes and the sanitizer lanes need nightly.
ci:
	$(MAKE) lint
	cargo fmt --check
	cargo clippy --release --all-targets -- -D warnings
	cargo build --release
	cargo test -q --release
	$(MAKE) cluster-smoke
	$(MAKE) chaos-smoke
	$(MAKE) cache-smoke
	$(MAKE) trace-smoke
	$(MAKE) bench-check
	$(MAKE) bench
	python -m pytest python/tests -q
