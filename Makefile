# simple-serve build entrypoints. `make artifacts` is the one the code
# cites: it lowers the L2 JAX model (with the L1 Pallas kernel inside) to
# HLO text + npy weights + manifest under artifacts/, incrementally.

.PHONY: artifacts artifacts-force build test figures ci

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

artifacts-force:
	cd python && python -m compile.aot --out-dir ../artifacts --force

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

figures: build
	cargo run --release -- figures

# What .github/workflows/ci.yml runs: fmt + clippy gates, release build +
# tests, python kernel/model tests (hypothesis optional — shim fallback).
ci:
	cargo fmt --check
	cargo clippy --release --all-targets -- -D warnings
	cargo build --release
	cargo test -q --release
	python -m pytest python/tests -q
