# simple-serve build entrypoints. `make artifacts` is the one the code
# cites: it lowers the L2 JAX model (with the L1 Pallas kernel inside) to
# HLO text + npy weights + manifest under artifacts/, incrementally.

.PHONY: artifacts artifacts-force build test figures

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

artifacts-force:
	cd python && python -m compile.aot --out-dir ../artifacts --force

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

figures: build
	cargo run --release -- figures
