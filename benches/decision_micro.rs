//! `cargo bench --bench decision_micro` — microbenchmarks of the decision
//! plane's hot-path kernels, with items/s reporting. These are the £3
//! targets the §Perf pass iterates on.
//!
//! Filter by substring: `cargo bench --bench decision_micro -- shvs`.
//! `--json <path>` additionally writes the machine-readable results
//! (`make bench` uses it for `BENCH_decision.json`, uploaded by CI so the
//! perf trajectory is tracked across PRs).

use simple_serve::bench::{
    black_box, render_table, results_to_json, run_case, BenchConfig, BenchResult,
};
use simple_serve::config::DecisionVariant;
use simple_serve::decision::penalties::{BatchHistory, SeqHistory};
use simple_serve::decision::{filter, DecisionPipeline, Precompute, SamplingParams};
use simple_serve::harness::measure::LogitsGen;
use simple_serve::ringbuf::spsc;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let mut json_path: Option<String> = None;
    let mut args: Vec<String> = Vec::new();
    let mut i = 0;
    while i < raw.len() {
        if raw[i] == "--json" {
            i += 1;
            json_path = raw.get(i).cloned();
        } else {
            args.push(raw[i].clone());
        }
        i += 1;
    }
    let filter_str: Option<&str> = args.iter().find(|a| !a.starts_with('-')).map(|s| s.as_str());
    let quick = args.iter().any(|a| a == "--quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let want = |name: &str| filter_str.is_none_or(|f| name.contains(f));
    let mut results: Vec<BenchResult> = Vec::new();

    const V: usize = 152_064; // QwQ-32B vocabulary
    const H: usize = 30_208;
    let gen = LogitsGen::new(V, 1.08, 42);
    let hot = gen.hot_vocab(H).into_arc();
    let params = SamplingParams::production_default();
    let unfiltered = SamplingParams { temperature: 0.9, ..Default::default() };

    // Pre-generate a few views so generation isn't in the timed region.
    let views: Vec<_> = (0..4).map(|i| gen.view(1, i, 1)).collect();
    let pres: Vec<_> = views
        .iter()
        .map(|v| Precompute::reference(v, 0, &hot, 0.9))
        .collect();
    let hist = BatchHistory::new(&[vec![1, 2, 3]], 64);

    // --- per-variant decision kernels ---
    for variant in [
        DecisionVariant::NaiveCpu,
        DecisionVariant::Parallel,
        DecisionVariant::Offloading,
        DecisionVariant::Shvs,
    ] {
        let name = format!("decide/{}", variant.name());
        if !want(&name) {
            continue;
        }
        let hot_arg = matches!(variant, DecisionVariant::Shvs).then(|| hot.clone());
        let mut pipe = DecisionPipeline::new(variant, hot_arg, 1);
        let mut it = 0u64;
        results.push(run_case(&name, &cfg, Some(1.0), || {
            let i = (it % 4) as usize;
            let d = pipe.decide(
                &views[i],
                0,
                &hist,
                0,
                &params,
                Some(&pres[i]),
                0,
                it,
            );
            black_box(d.token);
            it += 1;
        }));
    }

    // --- shvs fast path (unfiltered rejection sampling) ---
    if want("shvs_fast_path") {
        let mut pipe = DecisionPipeline::new(DecisionVariant::Shvs, Some(hot.clone()), 2);
        let mut it = 0u64;
        results.push(run_case("shvs_fast_path", &cfg, Some(1.0), || {
            let i = (it % 4) as usize;
            black_box(
                pipe.decide(&views[i], 0, &hist, 0, &unfiltered, Some(&pres[i]), 0, it)
                    .token,
            );
            it += 1;
        }));
    }

    // --- speculative-decoding verification (DESIGN.md §7) ---
    if want("verify") {
        use simple_serve::decision::draft::DraftProposer;
        use simple_serve::decision::verify::{verify_window, GrammarSlot};
        const K: usize = 4;
        let proposer = DraftProposer::new();
        let mut pipe = DecisionPipeline::new(DecisionVariant::Offloading, None, 4);
        let mut vhist = BatchHistory::new(&[vec![1, 2, 3]], 1 << 20);
        let mut grammar: GrammarSlot = None;
        let mut out: Vec<u32> = Vec::new();
        let chain: Vec<_> = (0..=K as u64).map(|j| gen.view(1, 10 + j, 1)).collect();
        // normalize per chain position: items/s = verified positions/s
        results.push(run_case("verify/spec_window_k4", &cfg, Some((K + 1) as f64), || {
            let base = out.len() as u64;
            let draft = proposer.propose(7, V, &[1, 2, 3], &out, K);
            let v = verify_window(
                &mut pipe, &chain, 0, &draft, &mut vhist, &mut grammar, &params, &[],
                0, base,
            );
            out.extend(black_box(&v.tokens));
        }));
    }

    // --- decision overlap: exposed (sync) vs hidden (async) ---
    // Per-iteration wall time at fixed batch/vocab with the decision plane
    // collected synchronously after the forward vs overlapped under the
    // next forward (the pipelined executor's win, measured in isolation:
    // the view generation stands in for the forward's wall time).
    if want("overlap") {
        use simple_serve::config::SamplerConfig;
        use simple_serve::decision::service::{ColumnMeta, IterationTask, SamplerService};
        const B: usize = 8;
        let svc_cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            seed: 7,
            ..Default::default()
        };
        let make_columns = |iter: u64| -> Vec<ColumnMeta> {
            (0..B)
                .map(|col| ColumnMeta { col, seq_id: col as u64, iteration: iter })
                .collect()
        };

        // exposed: forward, then block on decisions (synchronous engine)
        {
            let svc = SamplerService::start(&svc_cfg, None, 1 << 20);
            for s in 0..B as u64 {
                svc.register(s, &[1, 2, 3], &params);
            }
            let mut it = 0u64;
            results.push(run_case("overlap/exposed_sync", &cfg, Some(1.0), || {
                let view = gen.view(B, it, 1); // the "forward"
                svc.submit(IterationTask::single(it, view, make_columns(it), Vec::new()));
                let (d, _) = svc.collect(it, B);
                black_box(d.len());
                it += 1;
            }));
            svc.shutdown();
        }

        // hidden: submit, run the next "forward", then reap the previous
        // iteration's decisions (one microbatch in flight)
        {
            let svc = SamplerService::start(&svc_cfg, None, 1 << 20);
            for s in 0..B as u64 {
                svc.register(s, &[1, 2, 3], &params);
            }
            let mut it = 0u64;
            let mut outstanding: Option<u64> = None;
            results.push(run_case("overlap/hidden_async", &cfg, Some(1.0), || {
                let view = gen.view(B, it, 1); // the "forward"
                svc.submit(IterationTask::single(it, view, make_columns(it), Vec::new()));
                if let Some(prev) = outstanding.replace(it) {
                    let (d, _) = svc.collect(prev, B);
                    black_box(d.len());
                }
                it += 1;
            }));
            if let Some(prev) = outstanding {
                let _ = svc.collect(prev, B);
            }
            svc.shutdown();
        }
    }

    // --- cluster: shared sampler pool vs stranded per-replica pools ---
    // Two data-parallel replicas submit imbalanced iterations (6 vs 2
    // decision columns) at equal TOTAL sampler count (2). Per-replica
    // pools strand one sampler on the light replica while the heavy
    // replica's lone sampler serializes 6 columns; the shared pool
    // spreads all 8 columns by sequence ownership, 4 per sampler —
    // pooled decision capacity vs stranded (DESIGN.md §9). items/s =
    // decided columns/s, so shared should report ≥ per_replica.
    if want("cluster") {
        use simple_serve::config::SamplerConfig;
        use simple_serve::decision::service::{ColumnMeta, IterationTask, SamplerService};
        const HEAVY: usize = 6;
        const LIGHT: usize = 2;
        let svc_cfg = SamplerConfig {
            num_samplers: 1,
            variant: DecisionVariant::Offloading,
            seed: 11,
            ..Default::default()
        };
        let cols = |n: usize, base: u64, iter: u64| -> Vec<ColumnMeta> {
            (0..n)
                .map(|c| ColumnMeta { col: c, seq_id: base + c as u64, iteration: iter })
                .collect()
        };

        // stranded: one m=1 service per replica
        {
            let a = SamplerService::start(&svc_cfg, None, 1 << 20);
            let b = SamplerService::start(&svc_cfg, None, 1 << 20);
            for s in 0..HEAVY as u64 {
                a.register(s, &[1, 2, 3], &params);
            }
            for s in 0..LIGHT as u64 {
                b.register(HEAVY as u64 + s, &[1, 2, 3], &params);
            }
            let mut it = 0u64;
            results.push(run_case(
                "cluster/per_replica_pool",
                &cfg,
                Some((HEAVY + LIGHT) as f64),
                || {
                    let va = gen.view(HEAVY, it, 1);
                    let vb = gen.view(LIGHT, it, 1);
                    a.submit(IterationTask::single(it, va, cols(HEAVY, 0, it), Vec::new()));
                    b.submit(IterationTask::single(
                        it,
                        vb,
                        cols(LIGHT, HEAVY as u64, it),
                        Vec::new(),
                    ));
                    let (da, _) = a.collect(it, HEAVY);
                    let (db, _) = b.collect(it, LIGHT);
                    black_box(da.len() + db.len());
                    it += 1;
                },
            ));
            a.shutdown();
            b.shutdown();
        }

        // pooled: one m=2 service shared by both replicas, task ids
        // namespaced per replica exactly as Engine::with_shared_service does
        {
            let pool_cfg = SamplerConfig { num_samplers: 2, ..svc_cfg.clone() };
            let svc = SamplerService::start(&pool_cfg, None, 1 << 20);
            for s in 0..(HEAVY + LIGHT) as u64 {
                svc.register(s, &[1, 2, 3], &params);
            }
            let mut it = 0u64;
            results.push(run_case(
                "cluster/shared_pool",
                &cfg,
                Some((HEAVY + LIGHT) as f64),
                || {
                    let va = gen.view(HEAVY, it, 1);
                    let vb = gen.view(LIGHT, it, 1);
                    let (ta, tb) = ((1u64 << 48) | it, (2u64 << 48) | it);
                    svc.submit(IterationTask::single(ta, va, cols(HEAVY, 0, it), Vec::new()));
                    svc.submit(IterationTask::single(
                        tb,
                        vb,
                        cols(LIGHT, HEAVY as u64, it),
                        Vec::new(),
                    ));
                    let (da, _) = svc.collect(ta, HEAVY);
                    let (db, _) = svc.collect(tb, LIGHT);
                    black_box(da.len() + db.len());
                    it += 1;
                },
            ));
            svc.shutdown();
        }
    }

    // --- truncation-first vs sort-based filtering ---
    if want("filter") {
        let pairs: Vec<(u32, f32)> = {
            let mut p = Vec::with_capacity(V);
            views[0].for_each_logit(0, |v, z| p.push((v as u32, z)));
            p
        };
        let p2 = pairs.clone();
        results.push(run_case("filter/truncation_first", &cfg, Some(V as f64), || {
            black_box(filter::truncate(pairs.clone(), &params).len());
        }));
        results.push(run_case("filter/sort_based", &cfg, Some(V as f64), || {
            black_box(filter::truncate_sort_based(p2.clone(), &params).len());
        }));
    }

    // --- penalty state updates: incremental vs rebuild ---
    if want("penalties") {
        let mut bh = BatchHistory::new(&[vec![1, 2, 3]], 4096);
        for i in 0..1000u32 {
            bh.append_row(&[i % 997]);
        }
        results.push(run_case("penalties/incremental_append", &cfg, Some(1.0), || {
            let mut h = SeqHistory::new(&[1, 2, 3]);
            for i in 0..64u32 {
                h.append(i % 17);
            }
            black_box(h.num_penalized());
        }));
        results.push(run_case("penalties/naive_rebuild_1k", &cfg, Some(1.0), || {
            black_box(bh.rebuild(0).len());
        }));
    }

    // --- ring buffer transfer ---
    if want("ringbuf") {
        results.push(run_case("ringbuf/spsc_push_pop_1k", &cfg, Some(1000.0), || {
            let (p, c) = spsc::ring::<u64>(256);
            for i in 0..1000u64 {
                p.try_push(i).ok();
                black_box(c.try_pop().ok());
            }
        }));
    }

    // --- zero-copy sharded reads ---
    if want("tensor") {
        let view4 = gen.view(4, 0, 4);
        results.push(run_case("tensor/for_each_logit_152k", &cfg, Some(V as f64), || {
            let mut acc = 0.0f32;
            view4.for_each_logit(1, |_, z| acc += z);
            black_box(acc);
        }));
        let ids: Vec<u32> = hot.ids().to_vec();
        let mut out = Vec::new();
        results.push(run_case("tensor/gather_hot_30k", &cfg, Some(H as f64), || {
            view4.gather(2, &ids, &mut out);
            black_box(out.len());
        }));
    }

    // --- chaos: sampler crash-recovery pause vs the healthy collect ---
    // Each `recovery_pause` iteration kills one sampler just before the
    // task, so the collect pays detection (the starvation timeout) +
    // respawn + registry replay + task resubmission — the recovery pause
    // `serve --chaos` runs pay, measured in isolation against the same
    // submit/collect loop with no faults.
    if want("chaos") {
        use simple_serve::config::SamplerConfig;
        use simple_serve::decision::service::{ColumnMeta, IterationTask, SamplerService};
        const B: usize = 4;
        let svc_cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            seed: 13,
            ..Default::default()
        };
        let make_columns = |iter: u64| -> Vec<ColumnMeta> {
            (0..B)
                .map(|col| ColumnMeta { col, seq_id: col as u64, iteration: iter })
                .collect()
        };
        {
            let svc = SamplerService::start(&svc_cfg, None, 1 << 20);
            for s in 0..B as u64 {
                svc.register(s, &[1, 2, 3], &params);
            }
            let mut it = 0u64;
            results.push(run_case("chaos/healthy_collect", &cfg, Some(1.0), || {
                let view = gen.view(B, it, 1);
                svc.submit(IterationTask::single(it, view, make_columns(it), Vec::new()));
                let (d, _) = svc.collect(it, B);
                black_box(d.len());
                it += 1;
            }));
            svc.shutdown();
        }
        {
            let svc = SamplerService::start(&svc_cfg, None, 1 << 20);
            for s in 0..B as u64 {
                svc.register(s, &[1, 2, 3], &params);
            }
            let mut it = 0u64;
            results.push(run_case("chaos/recovery_pause", &cfg, Some(1.0), || {
                // alternate victims so the crash-loop breaker never trips
                svc.inject_sampler_crash((it % 2) as usize);
                let view = gen.view(B, it, 1);
                svc.submit(IterationTask::single(it, view, make_columns(it), Vec::new()));
                let (d, _) = svc.collect(it, B);
                black_box(d.len());
                it += 1;
            }));
            svc.shutdown();
        }
    }

    println!("{}", render_table("decision-plane microbenchmarks", &results));
    if let Some(path) = json_path {
        simple_serve::util::json::write_json_file(
            std::path::Path::new(&path),
            &results_to_json(&results),
        )
        .expect("write bench json");
        println!("wrote {path}");
    }
}
