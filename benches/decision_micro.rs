//! `cargo bench --bench decision_micro` — microbenchmarks of the decision
//! plane's hot-path kernels, with items/s reporting. These are the £3
//! targets the §Perf pass iterates on.
//!
//! Filter by substring: `cargo bench --bench decision_micro -- shvs`.
//! `--json <path>` additionally writes the machine-readable results
//! (`make bench` uses it for `BENCH_decision.json`, uploaded by CI so the
//! perf trajectory is tracked across PRs).

use simple_serve::bench::{
    black_box, render_table, results_to_json, run_case, BenchConfig, BenchResult,
};
use simple_serve::config::DecisionVariant;
use simple_serve::decision::penalties::{BatchHistory, SeqHistory};
use simple_serve::decision::{
    filter, DecisionPipeline, DenseKernel, KernelBackend, Precompute, SamplingParams,
};
use simple_serve::harness::measure::LogitsGen;
use simple_serve::ringbuf::spsc;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let mut json_path: Option<String> = None;
    let mut args: Vec<String> = Vec::new();
    let mut i = 0;
    while i < raw.len() {
        if raw[i] == "--json" {
            i += 1;
            json_path = raw.get(i).cloned();
        } else {
            args.push(raw[i].clone());
        }
        i += 1;
    }
    let filter_str: Option<&str> = args.iter().find(|a| !a.starts_with('-')).map(|s| s.as_str());
    let quick = args.iter().any(|a| a == "--quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let want = |name: &str| filter_str.is_none_or(|f| name.contains(f));
    let mut results: Vec<BenchResult> = Vec::new();

    const V: usize = 152_064; // QwQ-32B vocabulary
    const H: usize = 30_208;
    let gen = LogitsGen::new(V, 1.08, 42);
    let hot = gen.hot_vocab(H).into_arc();
    let params = SamplingParams::production_default();
    let unfiltered = SamplingParams { temperature: 0.9, ..Default::default() };

    // Pre-generate a few views so generation isn't in the timed region.
    let views: Vec<_> = (0..4).map(|i| gen.view(1, i, 1)).collect();
    let pres: Vec<_> = views
        .iter()
        .map(|v| Precompute::reference(v, 0, &hot, 0.9))
        .collect();
    let hist = BatchHistory::new(&[vec![1, 2, 3]], 64);

    // --- per-variant decision kernels ---
    for variant in [
        DecisionVariant::NaiveCpu,
        DecisionVariant::Parallel,
        DecisionVariant::Offloading,
        DecisionVariant::Shvs,
    ] {
        let name = format!("decide/{}", variant.name());
        if !want(&name) {
            continue;
        }
        let hot_arg = matches!(variant, DecisionVariant::Shvs).then(|| hot.clone());
        let mut pipe = DecisionPipeline::new(variant, hot_arg, 1);
        let mut it = 0u64;
        results.push(run_case(&name, &cfg, Some(1.0), || {
            let i = (it % 4) as usize;
            let d = pipe.decide(
                &views[i],
                0,
                &hist,
                0,
                &params,
                Some(&pres[i]),
                0,
                it,
            );
            black_box(d.token);
            it += 1;
        }));
    }

    // --- shvs fast path (unfiltered rejection sampling) ---
    if want("shvs_fast_path") {
        let mut pipe = DecisionPipeline::new(DecisionVariant::Shvs, Some(hot.clone()), 2);
        let mut it = 0u64;
        results.push(run_case("shvs_fast_path", &cfg, Some(1.0), || {
            let i = (it % 4) as usize;
            black_box(
                pipe.decide(&views[i], 0, &hist, 0, &unfiltered, Some(&pres[i]), 0, it)
                    .token,
            );
            it += 1;
        }));
    }

    // --- fused single-pass dense kernels: scalar vs 8-wide lanes ---
    // One full production column (penalties → top-k/top-p/min-p → stable
    // softmax weights → draw) at a 32k vocabulary, per backend. items/s =
    // columns/s, so per-column ns = 1e9 / items_per_sec; `make bench-check`
    // gates simd ≥ 1.5× scalar on this pair (DESIGN.md §12).
    if want("kernels") {
        const KV: usize = 32_768;
        let kgen = LogitsGen::new(KV, 1.08, 7);
        let kviews: Vec<_> = (0..4).map(|i| kgen.view(1, i, 1)).collect();
        let mut khist = SeqHistory::new(&[1, 2, 3]);
        for t in 0..48u32 {
            khist.append(t % 29);
        }
        for (backend, name) in [
            (KernelBackend::Scalar, "kernels/scalar_penalty_filter_softmax"),
            (KernelBackend::Simd, "kernels/simd_penalty_filter_softmax"),
        ] {
            if !want(name) {
                continue;
            }
            let mut kern = DenseKernel::new(backend);
            let mut it = 0u64;
            results.push(run_case(name, &cfg, Some(1.0), || {
                let i = (it % 4) as usize;
                let u = ((it % 1013) as f64 + 0.5) / 1013.0;
                black_box(kern.decide(&kviews[i], 0, &khist, &params, u));
                it += 1;
            }));
        }
    }

    // --- speculative-decoding verification (DESIGN.md §7) ---
    if want("verify") {
        use simple_serve::decision::draft::DraftProposer;
        use simple_serve::decision::verify::{verify_window, GrammarSlot};
        const K: usize = 4;
        let proposer = DraftProposer::new();
        let mut pipe = DecisionPipeline::new(DecisionVariant::Offloading, None, 4);
        let mut vhist = BatchHistory::new(&[vec![1, 2, 3]], 1 << 20);
        let mut grammar: GrammarSlot = None;
        let mut out: Vec<u32> = Vec::new();
        let chain: Vec<_> = (0..=K as u64).map(|j| gen.view(1, 10 + j, 1)).collect();
        // normalize per chain position: items/s = verified positions/s
        results.push(run_case("verify/spec_window_k4", &cfg, Some((K + 1) as f64), || {
            let base = out.len() as u64;
            let draft = proposer.propose(7, V, &[1, 2, 3], &out, K);
            let v = verify_window(
                &mut pipe, &chain, 0, &draft, &mut vhist, &mut grammar, &params, &[],
                0, base,
            );
            out.extend(black_box(&v.tokens));
        }));
    }

    // --- decision overlap: exposed (sync) vs hidden (async) ---
    // Per-iteration wall time at fixed batch/vocab with the decision plane
    // collected synchronously after the forward vs overlapped under the
    // next forward (the pipelined executor's win, measured in isolation:
    // the view generation stands in for the forward's wall time).
    if want("overlap") {
        use simple_serve::config::SamplerConfig;
        use simple_serve::decision::service::{ColumnMeta, IterationTask, SamplerService};
        const B: usize = 8;
        let svc_cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            seed: 7,
            ..Default::default()
        };
        let make_columns = |iter: u64| -> Vec<ColumnMeta> {
            (0..B)
                .map(|col| ColumnMeta { col, seq_id: col as u64, iteration: iter })
                .collect()
        };

        // exposed: forward, then block on decisions (synchronous engine)
        {
            let svc = SamplerService::start(&svc_cfg, None, 1 << 20);
            let handles: Vec<_> =
                (0..B as u64).map(|s| svc.register(s, &[1, 2, 3], &params)).collect();
            let mut it = 0u64;
            results.push(run_case("overlap/exposed_sync", &cfg, Some(1.0), || {
                let view = gen.view(B, it, 1); // the "forward"
                let recs = handles.iter().cloned().map(Some).collect();
                svc.submit(IterationTask::single(it, view, make_columns(it), recs, Vec::new()));
                let (d, _) = svc.collect(it, B);
                black_box(d.len());
                it += 1;
            }));
            svc.shutdown();
        }

        // hidden: submit, run the next "forward", then reap the previous
        // iteration's decisions (one microbatch in flight)
        {
            let svc = SamplerService::start(&svc_cfg, None, 1 << 20);
            let handles: Vec<_> =
                (0..B as u64).map(|s| svc.register(s, &[1, 2, 3], &params)).collect();
            let mut it = 0u64;
            let mut outstanding: Option<u64> = None;
            results.push(run_case("overlap/hidden_async", &cfg, Some(1.0), || {
                let view = gen.view(B, it, 1); // the "forward"
                let recs = handles.iter().cloned().map(Some).collect();
                svc.submit(IterationTask::single(it, view, make_columns(it), recs, Vec::new()));
                if let Some(prev) = outstanding.replace(it) {
                    let (d, _) = svc.collect(prev, B);
                    black_box(d.len());
                }
                it += 1;
            }));
            if let Some(prev) = outstanding {
                let _ = svc.collect(prev, B);
            }
            svc.shutdown();
        }
    }

    // --- cluster: shared sampler pool vs stranded per-replica pools ---
    // Two data-parallel replicas submit imbalanced iterations (6 vs 2
    // decision columns) at equal TOTAL sampler count (2). Per-replica
    // pools strand one sampler on the light replica while the heavy
    // replica's lone sampler serializes 6 columns; the shared pool
    // spreads all 8 columns by sequence ownership, 4 per sampler —
    // pooled decision capacity vs stranded (DESIGN.md §9). items/s =
    // decided columns/s, so shared should report ≥ per_replica.
    if want("cluster") {
        use simple_serve::config::SamplerConfig;
        use simple_serve::decision::service::{ColumnMeta, IterationTask, SamplerService};
        const HEAVY: usize = 6;
        const LIGHT: usize = 2;
        let svc_cfg = SamplerConfig {
            num_samplers: 1,
            variant: DecisionVariant::Offloading,
            seed: 11,
            ..Default::default()
        };
        let cols = |n: usize, base: u64, iter: u64| -> Vec<ColumnMeta> {
            (0..n)
                .map(|c| ColumnMeta { col: c, seq_id: base + c as u64, iteration: iter })
                .collect()
        };

        // stranded: one m=1 service per replica
        {
            let a = SamplerService::start(&svc_cfg, None, 1 << 20);
            let b = SamplerService::start(&svc_cfg, None, 1 << 20);
            let ha: Vec<_> =
                (0..HEAVY as u64).map(|s| a.register(s, &[1, 2, 3], &params)).collect();
            let hb: Vec<_> = (0..LIGHT as u64)
                .map(|s| b.register(HEAVY as u64 + s, &[1, 2, 3], &params))
                .collect();
            let mut it = 0u64;
            results.push(run_case(
                "cluster/per_replica_pool",
                &cfg,
                Some((HEAVY + LIGHT) as f64),
                || {
                    let va = gen.view(HEAVY, it, 1);
                    let vb = gen.view(LIGHT, it, 1);
                    let ra = ha.iter().cloned().map(Some).collect();
                    let rb = hb.iter().cloned().map(Some).collect();
                    a.submit(IterationTask::single(it, va, cols(HEAVY, 0, it), ra, Vec::new()));
                    b.submit(IterationTask::single(
                        it,
                        vb,
                        cols(LIGHT, HEAVY as u64, it),
                        rb,
                        Vec::new(),
                    ));
                    let (da, _) = a.collect(it, HEAVY);
                    let (db, _) = b.collect(it, LIGHT);
                    black_box(da.len() + db.len());
                    it += 1;
                },
            ));
            a.shutdown();
            b.shutdown();
        }

        // pooled: one m=2 service shared by both replicas, task ids
        // namespaced per replica exactly as Engine::with_shared_service does
        {
            let pool_cfg = SamplerConfig { num_samplers: 2, ..svc_cfg.clone() };
            let svc = SamplerService::start(&pool_cfg, None, 1 << 20);
            let hs: Vec<_> = (0..(HEAVY + LIGHT) as u64)
                .map(|s| svc.register(s, &[1, 2, 3], &params))
                .collect();
            let mut it = 0u64;
            results.push(run_case(
                "cluster/shared_pool",
                &cfg,
                Some((HEAVY + LIGHT) as f64),
                || {
                    let va = gen.view(HEAVY, it, 1);
                    let vb = gen.view(LIGHT, it, 1);
                    let (ta, tb) = ((1u64 << 48) | it, (2u64 << 48) | it);
                    let ra = hs[..HEAVY].iter().cloned().map(Some).collect();
                    let rb = hs[HEAVY..].iter().cloned().map(Some).collect();
                    svc.submit(IterationTask::single(ta, va, cols(HEAVY, 0, it), ra, Vec::new()));
                    svc.submit(IterationTask::single(
                        tb,
                        vb,
                        cols(LIGHT, HEAVY as u64, it),
                        rb,
                        Vec::new(),
                    ));
                    let (da, _) = svc.collect(ta, HEAVY);
                    let (db, _) = svc.collect(tb, LIGHT);
                    black_box(da.len() + db.len());
                    it += 1;
                },
            ));
            svc.shutdown();
        }

        // --- fleet scale sweep: the contention cliff (DESIGN.md §11) ---
        // R submitter threads (one per simulated replica) each publish a
        // B-column iteration into the pool and block on its collect, every
        // bench iteration, at equal TOTAL sampler count (R) in both modes.
        // Under the old global service mutex the shared pool fell off a
        // cliff as R grew; the lock-free pool's bar is shared-pool
        // per-replica throughput within ~10% of per-replica pools at every
        // R (items/s = decided columns/s across the fleet, so compare
        // shared_pool_r{R} against per_replica_pool_r{R} directly).
        const SB: usize = 4;
        let scale_cols = |base: u64, iter: u64| -> Vec<ColumnMeta> {
            (0..SB)
                .map(|c| ColumnMeta { col: c, seq_id: base + c as u64, iteration: iter })
                .collect()
        };
        for r in [1usize, 2, 4, 8] {
            // stranded: R independent m=1 services
            {
                let svcs: Vec<_> = (0..r)
                    .map(|_| SamplerService::start(&svc_cfg, None, 1 << 20))
                    .collect();
                let handles: Vec<Vec<_>> = svcs
                    .iter()
                    .enumerate()
                    .map(|(ri, svc)| {
                        (0..SB as u64)
                            .map(|s| {
                                svc.register(ri as u64 * SB as u64 + s, &[1, 2, 3], &params)
                            })
                            .collect()
                    })
                    .collect();
                let mut it = 0u64;
                results.push(run_case(
                    &format!("cluster/per_replica_pool_r{r}"),
                    &cfg,
                    Some((r * SB) as f64),
                    || {
                        let now = it;
                        std::thread::scope(|scope| {
                            for (ri, svc) in svcs.iter().enumerate() {
                                let hs = &handles[ri];
                                let gen = &gen;
                                scope.spawn(move || {
                                    let base = ri as u64 * SB as u64;
                                    let view = gen.view(SB, now, 1);
                                    let recs = hs.iter().cloned().map(Some).collect();
                                    svc.submit(IterationTask::single(
                                        now,
                                        view,
                                        scale_cols(base, now),
                                        recs,
                                        Vec::new(),
                                    ));
                                    let (d, _) = svc.collect(now, SB);
                                    black_box(d.len());
                                });
                            }
                        });
                        it += 1;
                    },
                ));
                for svc in svcs {
                    svc.shutdown();
                }
            }

            // pooled: one m=R service shared by all R replicas, task ids
            // namespaced per replica (Engine::with_shared_service idiom)
            {
                let pool_cfg = SamplerConfig { num_samplers: r, ..svc_cfg.clone() };
                let svc = SamplerService::start(&pool_cfg, None, 1 << 20);
                let handles: Vec<Vec<_>> = (0..r)
                    .map(|ri| {
                        (0..SB as u64)
                            .map(|s| {
                                svc.register(ri as u64 * SB as u64 + s, &[1, 2, 3], &params)
                            })
                            .collect()
                    })
                    .collect();
                let mut it = 0u64;
                results.push(run_case(
                    &format!("cluster/shared_pool_r{r}"),
                    &cfg,
                    Some((r * SB) as f64),
                    || {
                        let now = it;
                        let svc = &svc;
                        std::thread::scope(|scope| {
                            for (ri, hs) in handles.iter().enumerate() {
                                let gen = &gen;
                                scope.spawn(move || {
                                    let base = ri as u64 * SB as u64;
                                    let task = ((ri as u64 + 1) << 48) | now;
                                    let view = gen.view(SB, now, 1);
                                    let recs = hs.iter().cloned().map(Some).collect();
                                    svc.submit(IterationTask::single(
                                        task,
                                        view,
                                        scale_cols(base, now),
                                        recs,
                                        Vec::new(),
                                    ));
                                    let (d, _) = svc.collect(task, SB);
                                    black_box(d.len());
                                });
                            }
                        });
                        it += 1;
                    },
                ));
                svc.shutdown();
            }
        }
    }

    // --- kvcache: radix prefix admission — hit vs miss vs COW fork ---
    // One admission + release per iteration against a warm radix index
    // (DESIGN.md §13), including a fixed per-token materialization cost
    // for every token the admission must actually prefill — the work a
    // prefix hit skips. prefix_hit shares a 15-block published stem and
    // materializes only the 16-token private tail; prefix_miss matches
    // nothing and materializes all 256 tokens; cow_fork's match covers the
    // whole context, so the cap cuts mid-block and the tail block is
    // forked copy-on-write. `make bench-check` gates hit ≥ 5× miss.
    if want("kvcache") {
        use simple_serve::engine::KvAllocator;
        const BT: usize = 16;
        const CTX_BLOCKS: usize = 16;
        let materialize = |tokens: &[u32]| {
            // Serial per-token KV materialization stand-in (128 dependent
            // rounds/token ~ a head_dim-sized row compute); the dependency
            // chain keeps the cost per token honest under optimization.
            let mut h = 0x9e37_79b9_7f4a_7c15u64;
            for &t in tokens {
                for _ in 0..128 {
                    h = h.wrapping_mul(0x100_0000_01b3).rotate_left(7) ^ t as u64;
                }
            }
            black_box(h);
        };
        let ctx: Vec<u32> = (0..(CTX_BLOCKS * BT) as u32).map(|i| i * 7 + 3).collect();
        let stem = &ctx[..(CTX_BLOCKS - 1) * BT];

        if want("kvcache/prefix_hit") {
            let mut alloc = KvAllocator::new(4096, BT);
            alloc.admit(0, stem.len()).expect("publisher admission");
            alloc.publish(0, stem).expect("publish stem");
            let mut it = 0u64;
            results.push(run_case("kvcache/prefix_hit", &cfg, Some(1.0), || {
                let out = alloc.admit_shared(it + 1, &ctx, ctx.len() + 1).expect("hit");
                materialize(&ctx[out.cached_tokens..]);
                alloc.release(it + 1).expect("release");
                it += 1;
            }));
        }

        if want("kvcache/prefix_miss") {
            let mut alloc = KvAllocator::new(4096, BT);
            alloc.admit(0, stem.len()).expect("publisher admission");
            alloc.publish(0, stem).expect("publish stem");
            let miss_ctx: Vec<u32> = ctx.iter().map(|&t| t ^ 0x8000_0000).collect();
            let mut it = 0u64;
            results.push(run_case("kvcache/prefix_miss", &cfg, Some(1.0), || {
                let out =
                    alloc.admit_shared(it + 1, &miss_ctx, miss_ctx.len() + 1).expect("miss");
                materialize(&miss_ctx[out.cached_tokens..]);
                alloc.release(it + 1).expect("release");
                it += 1;
            }));
        }

        if want("kvcache/cow_fork") {
            let mut alloc = KvAllocator::new(4096, BT);
            alloc.admit(0, ctx.len()).expect("publisher admission");
            alloc.publish(0, &ctx).expect("publish full context");
            let mut it = 0u64;
            results.push(run_case("kvcache/cow_fork", &cfg, Some(1.0), || {
                let out = alloc.admit_shared(it + 1, &ctx, ctx.len() + 1).expect("fork");
                debug_assert!(out.cow_fork);
                materialize(&ctx[out.cached_tokens..]);
                alloc.release(it + 1).expect("release");
                it += 1;
            }));
        }
    }

    // --- truncation-first vs sort-based filtering ---
    if want("filter") {
        let pairs: Vec<(u32, f32)> = {
            let mut p = Vec::with_capacity(V);
            views[0].for_each_logit(0, |v, z| p.push((v as u32, z)));
            p
        };
        let p2 = pairs.clone();
        results.push(run_case("filter/truncation_first", &cfg, Some(V as f64), || {
            black_box(filter::truncate(pairs.clone(), &params).len());
        }));
        results.push(run_case("filter/sort_based", &cfg, Some(V as f64), || {
            black_box(filter::truncate_sort_based(p2.clone(), &params).len());
        }));
    }

    // --- penalty state updates: incremental vs rebuild ---
    if want("penalties") {
        let mut bh = BatchHistory::new(&[vec![1, 2, 3]], 4096);
        for i in 0..1000u32 {
            bh.append_row(&[i % 997]);
        }
        results.push(run_case("penalties/incremental_append", &cfg, Some(1.0), || {
            let mut h = SeqHistory::new(&[1, 2, 3]);
            for i in 0..64u32 {
                h.append(i % 17);
            }
            black_box(h.num_penalized());
        }));
        results.push(run_case("penalties/naive_rebuild_1k", &cfg, Some(1.0), || {
            black_box(bh.rebuild(0).len());
        }));
    }

    // --- ring buffer transfer ---
    if want("ringbuf") {
        results.push(run_case("ringbuf/spsc_push_pop_1k", &cfg, Some(1000.0), || {
            let (p, c) = spsc::ring::<u64>(256);
            for i in 0..1000u64 {
                p.try_push(i).ok();
                black_box(c.try_pop().ok());
            }
        }));
        // the shared-pool substrate: single-threaded push/pop cost of the
        // lock-free MPMC ring (per-slot lap counters + CAS head/tail)
        results.push(run_case("ringbuf/mpmc_push_pop_1k", &cfg, Some(1000.0), || {
            let ring = simple_serve::ringbuf::mpmc::Ring::<u64>::new(256);
            for i in 0..1000u64 {
                ring.try_push(i).ok();
                black_box(ring.try_pop().ok());
            }
        }));
    }

    // --- zero-copy sharded reads ---
    if want("tensor") {
        let view4 = gen.view(4, 0, 4);
        results.push(run_case("tensor/for_each_logit_152k", &cfg, Some(V as f64), || {
            let mut acc = 0.0f32;
            view4.for_each_logit(1, |_, z| acc += z);
            black_box(acc);
        }));
        let ids: Vec<u32> = hot.ids().to_vec();
        let mut out = Vec::new();
        results.push(run_case("tensor/gather_hot_30k", &cfg, Some(H as f64), || {
            view4.gather(2, &ids, &mut out);
            black_box(out.len());
        }));
    }

    // --- chaos: sampler crash-recovery pause vs the healthy collect ---
    // Each `recovery_pause` iteration kills one sampler just before the
    // task, so the collect pays detection (the dead-flag sweep) + claim
    // release + shard-message resubmission + respawn — the recovery pause
    // `serve --chaos` runs pay, measured in isolation against the same
    // submit/collect loop with no faults (lazy state rebuild from the
    // replay records lands on the next decide, not here).
    if want("chaos") {
        use simple_serve::config::SamplerConfig;
        use simple_serve::decision::service::{ColumnMeta, IterationTask, SamplerService};
        const B: usize = 4;
        let svc_cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            seed: 13,
            ..Default::default()
        };
        let make_columns = |iter: u64| -> Vec<ColumnMeta> {
            (0..B)
                .map(|col| ColumnMeta { col, seq_id: col as u64, iteration: iter })
                .collect()
        };
        {
            let svc = SamplerService::start(&svc_cfg, None, 1 << 20);
            let handles: Vec<_> =
                (0..B as u64).map(|s| svc.register(s, &[1, 2, 3], &params)).collect();
            let mut it = 0u64;
            results.push(run_case("chaos/healthy_collect", &cfg, Some(1.0), || {
                let view = gen.view(B, it, 1);
                let recs = handles.iter().cloned().map(Some).collect();
                svc.submit(IterationTask::single(it, view, make_columns(it), recs, Vec::new()));
                let (d, _) = svc.collect(it, B);
                black_box(d.len());
                it += 1;
            }));
            svc.shutdown();
        }
        {
            let svc = SamplerService::start(&svc_cfg, None, 1 << 20);
            let handles: Vec<_> =
                (0..B as u64).map(|s| svc.register(s, &[1, 2, 3], &params)).collect();
            let mut it = 0u64;
            results.push(run_case("chaos/recovery_pause", &cfg, Some(1.0), || {
                // alternate victims so the crash-loop breaker never trips
                svc.inject_sampler_crash((it % 2) as usize);
                let view = gen.view(B, it, 1);
                let recs = handles.iter().cloned().map(Some).collect();
                svc.submit(IterationTask::single(it, view, make_columns(it), recs, Vec::new()));
                let (d, _) = svc.collect(it, B);
                black_box(d.len());
                it += 1;
            }));
            svc.shutdown();
        }
    }

    // --- trace: flight-recorder overhead on the shared-pool hot path ---
    // The same submit/collect loop with the flight recorder gated off vs
    // on (DESIGN.md §14). Off is one relaxed load per emit site and must
    // be indistinguishable from the tracing-free baseline; `make
    // bench-check` gates on ≥ 1/1.10 of off (≤ 10% overhead).
    if want("trace") {
        use simple_serve::config::SamplerConfig;
        use simple_serve::decision::service::{ColumnMeta, IterationTask, SamplerService};
        const B: usize = 8;
        let svc_cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            seed: 17,
            ..Default::default()
        };
        let make_columns = |iter: u64| -> Vec<ColumnMeta> {
            (0..B)
                .map(|col| ColumnMeta { col, seq_id: col as u64, iteration: iter })
                .collect()
        };
        for (on, name) in [(false, "trace/off"), (true, "trace/on")] {
            if !want(name) {
                continue;
            }
            simple_serve::trace::set_enabled(on);
            let svc = SamplerService::start(&svc_cfg, None, 1 << 20);
            let handles: Vec<_> =
                (0..B as u64).map(|s| svc.register(s, &[1, 2, 3], &params)).collect();
            let mut it = 0u64;
            results.push(run_case(name, &cfg, Some(B as f64), || {
                let view = gen.view(B, it, 1);
                let recs = handles.iter().cloned().map(Some).collect();
                svc.submit(IterationTask::single(it, view, make_columns(it), recs, Vec::new()));
                let (d, _) = svc.collect(it, B);
                black_box(d.len());
                it += 1;
            }));
            svc.shutdown();
            simple_serve::trace::set_enabled(false);
        }
        // the rings are bounded (overwrite-oldest), but clear them anyway
        // so no bench events leak into a later export from this process
        simple_serve::trace::clear();
    }

    println!("{}", render_table("decision-plane microbenchmarks", &results));
    // Per-column latency of the fused dense kernels (the §12 headline
    // number; items/iter = 1 column, so mean IS the per-column time).
    for r in results.iter().filter(|r| r.name.starts_with("kernels/")) {
        println!("{}: {:.1} ns/column", r.name, r.summary.mean * 1e9);
    }
    if let Some(path) = json_path {
        simple_serve::util::json::write_json_file(
            std::path::Path::new(&path),
            &results_to_json(&results),
        )
        .expect("write bench json");
        println!("wrote {path}");
    }
}
