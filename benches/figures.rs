//! `cargo bench --bench figures` — regenerate every paper table and figure
//! (DESIGN.md §4's per-experiment index) into `results/`.
//!
//! Pass figure ids to restrict: `cargo bench --bench figures -- fig3 fig10`.
//! Pass `--full` for paper-scale sweeps (default is the quick profile so CI
//! stays fast).

use simple_serve::harness::{self, Effort};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    // cargo bench passes `--bench`; ignore flags.
    let ids: Vec<&str> = args
        .iter()
        .filter(|a| !a.starts_with('-'))
        .map(|s| s.as_str())
        .collect();
    let ids: Vec<&str> = if ids.is_empty() {
        harness::ALL_EXPERIMENTS.to_vec()
    } else {
        ids
    };
    let effort = if full { Effort::Full } else { Effort::Quick };
    let dir = harness::default_results_dir();
    let mut failures = 0;
    for id in ids {
        let t0 = std::time::Instant::now();
        match harness::run_experiment(id, effort) {
            Ok(report) => {
                report.write(&dir).expect("write results");
                println!("[{:>8.2?}] {id:<7} {}", t0.elapsed(), report.title);
            }
            Err(e) => {
                eprintln!("{id}: ERROR {e:#}");
                failures += 1;
            }
        }
    }
    if failures > 0 {
        std::process::exit(1);
    }
    println!("\nresults written to {}", dir.display());
}
