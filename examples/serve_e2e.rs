//! END-TO-END VALIDATION (DESIGN.md §5): serve a batched ShareGPT-like
//! workload on the real AOT-compiled ~20M-parameter transformer through the
//! full three-layer stack, comparing the baseline serial epilogue against
//! SIMPLE's disaggregated decision plane, and report throughput + latency.
//!
//! Run: `make artifacts && cargo run --release --example serve_e2e`
//! (`--quick` serves fewer requests; `--model micro-test` for CI speed)
//!
//! Open-loop traffic: `--traffic {steady,burst,zipf} --rate <req/s>` stamps
//! arrivals onto the trace (default: closed loop, everything at t = 0) and
//! reports P95 TTFT/TPOT plus KV-pressure preemption counts — the
//! bursty-arrival scenario that stresses admit/preempt/resume churn.
//!
//! Speculative decoding: `--spec_k K` drafts K tokens per sequence per
//! iteration and verifies them in the decision plane (DESIGN.md §7). The
//! printed `stream digest` is a deterministic hash of every finished
//! sequence's tokens: for fixed seeds it is IDENTICAL for any K and any
//! sampler count m — verification is exact. `--loopy` serves the
//! motif-cycled (templated-traffic) trace where self-drafting gets
//! realistic acceptance rates; the per-variant line reports accepted
//! drafts / proposed and committed tokens per decision step.
//!
//! Overlapped execution (DESIGN.md §8): `--n_microbatches N --overlap`
//! splits the slot space into N in-flight microbatches so one microbatch's
//! decisions are sampled while another's forward runs; the per-variant
//! `overlap:` line reports the measured hidden fraction and last-stage
//! bubble. Stream digests stay bit-identical to the synchronous run for
//! any (N, overlap, m, spec_k) — overlap changes timing, never tokens.
//!
//! Cluster serving (DESIGN.md §9): `--replicas R [--route P]
//! [--shared_samplers] [--prefill_replicas N]` runs the same workload
//! through R data-parallel replicas behind the decision-plane-aware
//! router; the JSON gains per-replica and fleet-aggregate sections, and
//! the fleet stream digest stays bit-identical to a single-replica run
//! for every policy, replica count, and pool mode — routing moves work,
//! never decisions.

// Config structs are built by `default()` + field assignment (sweep-driver
// idiom); see the identical crate-level allow in lib.rs.
#![allow(clippy::field_reassign_with_default)]

use simple_serve::cluster::{Cluster, ClusterConfig};
use simple_serve::config::{DecisionVariant, EngineConfig};
use simple_serve::decision::HotVocab;
use simple_serve::engine::PjrtEngine;
use simple_serve::runtime::{default_artifacts_dir, Manifest, ModelRuntime};
use simple_serve::util::argparse::{Args, OptSpec};
use simple_serve::util::json::Json;
use simple_serve::workload::{self, TrafficPattern};

const SPECS: &[OptSpec] = &[
    OptSpec::value("model", "AOT model (tiny-30m | micro-test)"),
    OptSpec::value("requests", "number of requests"),
    OptSpec::value("samplers", "sampler count m"),
    OptSpec::value("traffic", "arrival pattern: steady|burst|zipf (default: closed loop)"),
    OptSpec::value("rate", "mean arrival rate in req/s (open loop; default 20)"),
    OptSpec::value("prefill_budget", "chunked-prefill token budget per iteration"),
    OptSpec::value("kv_blocks", "KV blocks (0 = never-preempt sizing; small = churn)"),
    OptSpec::value("spec_k", "speculative draft window per iteration (0 = off)"),
    OptSpec::value("n_microbatches", "in-flight microbatches (pipelined executor; default 1)"),
    OptSpec::value("idle_poll_us", "idle poll quantum in µs (0 = busy-poll)"),
    OptSpec::flag("overlap", "overlap the decision plane with forwards (DESIGN.md §8)"),
    OptSpec::flag("loopy", "motif-cycled prompts (speculation-friendly trace)"),
    OptSpec::flag("prefix_cache", "radix KV prefix reuse (DESIGN.md §13)"),
    OptSpec::flag("conv", "conversation-tree trace (Zipf-shared system prompts; prefix-cache-friendly)"),
    OptSpec::value("replicas", "data-parallel engine replicas (default 1)"),
    OptSpec::value("route", "routing policy: rr|least-outstanding|kv-pressure|session-affinity"),
    OptSpec::flag("shared_samplers", "one shared sampler pool for the whole fleet"),
    OptSpec::value("prefill_replicas", "DistServe-style split: prefill-only replicas"),
    OptSpec::value("kv_transfer_us", "simulated KV-transfer µs per context token"),
    OptSpec::value(
        "chaos",
        "fault plan: sampler:<id>@<iter>,replica:<id>@<n>,poison@<iter> (legacy; kills worker 0) (DESIGN.md §10)",
    ),
    OptSpec::flag("no_failover", "fail the run on replica death instead of requeueing"),
    OptSpec::value("trace", "write a Chrome-trace/Perfetto capture here (or SIMPLE_TRACE=)"),
    OptSpec::value("metrics_out", "write the Prometheus-style metrics exposition here"),
    OptSpec::flag("quick", "small run"),
];

/// Deterministic digest of the served token streams (the shared
/// [`simple_serve::util::stream_digest`], so the `overlap` harness and
/// this example hash identically).
fn stream_digest(finished: Vec<simple_serve::engine::Sequence>) -> u64 {
    simple_serve::util::stream_digest(
        finished.into_iter().map(|s| (s.request.id, s.output)).collect(),
    )
}

fn main() -> simple_serve::Result<()> {
    let args = Args::parse_env(SPECS, false)?;
    let trace_out = simple_serve::trace::init_capture(args.get("trace"));
    let quick = args.flag("quick");
    let model = args
        .get("model")
        .unwrap_or(if quick { "micro-test" } else { "tiny-30m" })
        .to_string();
    let n: usize = args.get_or("requests", if quick { 10 } else { 32 })?;
    let samplers: usize = args.get_or("samplers", 2)?;
    let traffic = match args.get("traffic") {
        Some(name) => Some(
            TrafficPattern::parse(name)
                .ok_or_else(|| anyhow::anyhow!("unknown traffic pattern {name}"))?,
        ),
        None => None,
    };
    let rate: f64 = args.get_or("rate", 20.0)?;
    let prefill_budget: usize = args.get_or("prefill_budget", 0)?;
    let kv_blocks: usize = args.get_or("kv_blocks", 0)?;
    let spec_k: usize = args.get_or("spec_k", 0)?;
    let n_microbatches: usize = args.get_or("n_microbatches", 1)?;
    let idle_poll_us: u64 = args.get_or("idle_poll_us", 200)?;
    let overlap = args.flag("overlap");
    let loopy = args.flag("loopy");
    let mut ccfg = ClusterConfig::default();
    ccfg.apply_args(&args)?;
    if let Some(spec) = args.get("chaos") {
        // fail loudly on a plan that cannot fire (wrong sampler/replica
        // ids) — a silently no-op injection makes a chaos run vacuous
        simple_serve::fault::FaultPlan::parse(spec)?.validate(samplers, ccfg.replicas)?;
    }

    let manifest = Manifest::load(&default_artifacts_dir())
        .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts` first"))?;

    match traffic {
        Some(p) => println!(
            "=== end-to-end serving: {model}, {n} requests, {} arrivals at {rate} req/s, \
             spec_k={spec_k} ===\n",
            p.name()
        ),
        None => println!(
            "=== end-to-end serving: {model}, {n} requests (closed loop), spec_k={spec_k} ===\n"
        ),
    }
    let mut results = Vec::new();
    let mut digests = Vec::new();
    let mut overlaps = Vec::new();
    let mut replica_sections = Vec::new();
    for variant in [DecisionVariant::GpuEpilogue, DecisionVariant::Shvs] {
        let spec = manifest.model(&model)?;
        let (vocab, max_seq) = (spec.vocab, spec.max_seq);
        let mut cfg = EngineConfig::default();
        cfg.sampler.variant = variant;
        cfg.sampler.num_samplers = samplers;
        cfg.prefill_token_budget = prefill_budget;
        cfg.kv_blocks = kv_blocks;
        cfg.prefix_cache = args.flag("prefix_cache");
        cfg.spec_k = spec_k;
        cfg.n_microbatches = n_microbatches;
        cfg.overlap = overlap;
        cfg.idle_poll_us = idle_poll_us;
        if let Some(spec) = args.get("chaos") {
            // engine-level fault domains; replica kills ride ccfg.faults
            // (ClusterConfig::apply_args parsed the same spec above)
            let (engine_faults, _) = simple_serve::fault::FaultPlan::parse(spec)?.split();
            cfg.faults = engine_faults;
        }
        // Offline-profiled hot set: the AOT model's Zipf head lives on
        // low ids by construction (see python/compile/model.py lm_bias).
        let h = (vocab / 5).min(32_768) as u32;
        let hot = (variant == DecisionVariant::Shvs)
            .then(|| HotVocab::new((0..h).collect(), vocab).into_arc());
        let mut trace = if args.flag("conv") {
            // conversation trees: `n` conversations, each turn extending
            // its history — the traffic shape prefix caching exists for
            workload::conversations(&workload::ConvConfig::sharegpt_like(n, vocab, max_seq))
        } else if loopy {
            workload::generate(&workload::TraceConfig::loopy(n, vocab, max_seq))
        } else {
            workload::generate(&workload::TraceConfig::sharegpt_like(n, vocab, max_seq))
        };
        if let Some(pattern) = traffic {
            pattern.stamp(&mut trace, rate, 11);
        }
        let expected: usize = trace.output_lens.iter().sum();
        // Either one engine or a routed fleet of them — same workload,
        // same expected tokens, same stream digest.
        let clustered = ccfg.replicas > 1 || ccfg.prefill_replicas > 0;
        let (summary, digest, ov, preemptions, gpu_util, cpu_util, spec_note) = if clustered
        {
            let mut vcfg = ccfg.clone();
            // the inline epilogue baseline has no service to share
            vcfg.shared_samplers &= variant != DecisionVariant::GpuEpilogue;
            vcfg.idle_poll_us = idle_poll_us;
            let artifacts = default_artifacts_dir();
            let model_name = model.clone();
            let mut cluster = Cluster::start(&cfg, &vcfg, hot, max_seq, move |_id| {
                ModelRuntime::load(&Manifest::load(&artifacts)?, &model_name)
            });
            cluster.run(trace.requests)?;
            let report = cluster.shutdown()?;
            let summary = report.recorder.summary();
            // Every request's final sequence is complete regardless of
            // faults; the recorder can under-count after a replica kill
            // (the corpse's partial recorder dies with it) but must never
            // invent tokens.
            let final_tokens: usize =
                report.finished.iter().map(|s| s.output.len()).sum();
            assert_eq!(final_tokens, expected, "all tokens produced");
            assert!(summary.tokens <= expected, "recorder must not invent tokens");
            for r in &report.per_replica {
                println!(
                    "[{}] replica {} [{}]: {:>7.0} tok/s | {} tokens | {} preemptions",
                    variant.name(),
                    r.id,
                    r.role.name(),
                    r.summary.throughput,
                    r.summary.tokens,
                    r.preemptions
                );
            }
            replica_sections.push((
                variant.name(),
                Json::Arr(
                    report
                        .per_replica
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::Num(r.id as f64)),
                                ("role", Json::Str(r.role.name().into())),
                                ("preemptions", Json::Num(r.preemptions as f64)),
                                ("summary", r.summary.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ));
            if report.recorder.recoveries() > 0 {
                println!(
                    "[{}] fault recovery: {} failover(s)/respawn(s), {} requeued, \
                     {:.2} ms",
                    variant.name(),
                    report.recorder.recoveries(),
                    report.requeued,
                    report.recorder.recovery_s() * 1e3
                );
            }
            let spec_note = if report.spec_windows > 0 {
                format!(
                    " | spec: {}/{} drafts accepted, {:.2} tok/step",
                    report.spec_accepted,
                    report.spec_proposed,
                    report.spec_committed as f64 / report.spec_windows as f64
                )
            } else {
                String::new()
            };
            (
                summary,
                report.stream_digest(),
                report.recorder.overlap_report(),
                report.preemptions,
                report.recorder.utilization("gpu"),
                report.recorder.utilization("cpu"),
                spec_note,
            )
        } else {
            let rt = ModelRuntime::load(&manifest, &model)?;
            let mut engine = PjrtEngine::new(rt, &cfg, hot);
            for r in trace.requests {
                engine.submit(r);
            }
            let summary = engine.run_until_idle()?;
            assert_eq!(summary.tokens, expected, "all tokens produced");
            let digest = stream_digest(engine.take_finished());
            let spec_note = if engine.spec_windows > 0 {
                format!(
                    " | spec: {}/{} drafts accepted, {:.2} tok/step",
                    engine.spec_accepted,
                    engine.spec_proposed,
                    engine.spec_committed as f64 / engine.spec_windows as f64
                )
            } else {
                String::new()
            };
            let ov = engine.overlap_report();
            let preemptions = engine.preemption_count();
            let gpu_util = engine.recorder.utilization("gpu");
            let cpu_util = engine.recorder.utilization("cpu");
            let (recorder, _) = engine.shutdown();
            if recorder.recoveries() > 0 {
                println!(
                    "[{}] fault recovery: {} sampler respawn(s), {:.2} ms",
                    variant.name(),
                    recorder.recoveries(),
                    recorder.recovery_s() * 1e3
                );
            }
            (summary, digest, ov, preemptions, gpu_util, cpu_util, spec_note)
        };
        println!(
            "[{}] {:>7.0} tok/s | TPOT p50 {:>6.2} ms  p95 {:>6.2} ms | \
             TTFT p50 {:>6.1} ms  p95 {:>6.1} ms | gpu util {:.0}% cpu util {:.0}% | \
             {} preemptions{}",
            variant.name(),
            summary.throughput,
            summary.tpot.p50 * 1e3,
            summary.tpot.p95 * 1e3,
            summary.ttft.p50 * 1e3,
            summary.ttft.p95 * 1e3,
            gpu_util * 100.0,
            cpu_util * 100.0,
            preemptions,
            spec_note,
        );
        println!("[{}] stream digest: {digest:016x}", variant.name());
        if ov.decision_busy_s > 0.0 {
            println!(
                "[{}] overlap: {:.0}% of decision time hidden under forwards | \
                 exposed {:.2} ms | last-stage bubble {:.1}% | {} microbatch(es)",
                variant.name(),
                ov.overlap_fraction * 100.0,
                ov.exposed_wait_s * 1e3,
                ov.last_stage_bubble * 100.0,
                ov.microbatches,
            );
        }
        results.push((variant.name(), summary));
        digests.push((variant.name(), digest));
        overlaps.push((variant.name(), ov));
    }

    let base = &results[0].1;
    let simple = &results[1].1;
    println!(
        "\nSIMPLE vs baseline epilogue: throughput ×{:.2}, TPOT p95 {:+.0}%",
        simple.throughput / base.throughput,
        (simple.tpot.p95 / base.tpot.p95 - 1.0) * 100.0
    );
    if spec_k > 0 {
        println!(
            "(compare `stream digest` lines against a --spec_k 0 run: they must match \
             — verification is exact for any k and m)"
        );
    }
    if overlap || n_microbatches > 1 {
        println!(
            "(compare `stream digest` lines against a run without --overlap/--n_microbatches: \
             they must match — overlap changes timing, never tokens; \
             `figures --experiments overlap` compares the measured hidden fraction \
             against the simulator's prediction)"
        );
    }
    if ccfg.replicas > 1 || ccfg.prefill_replicas > 0 {
        println!(
            "(compare `stream digest` lines against a --replicas 1 run: they must \
             match for every policy, replica count, and pool mode — routing moves \
             work, never decisions)"
        );
    }
    // Record machine-readable results for EXPERIMENTS.md.
    let out = Json::obj(vec![
        ("model", Json::Str(model)),
        ("requests", Json::Num(n as f64)),
        ("spec_k", Json::Num(spec_k as f64)),
        ("n_microbatches", Json::Num(n_microbatches as f64)),
        ("overlap", Json::Bool(overlap)),
        ("replicas", Json::Num(ccfg.replicas as f64)),
        ("route", Json::Str(ccfg.policy.name().to_string())),
        ("shared_samplers", Json::Bool(ccfg.shared_samplers)),
        ("prefill_replicas", Json::Num(ccfg.prefill_replicas as f64)),
        (
            // per-replica sections (fleet runs only); the `baseline` /
            // `simple` entries below are the fleet aggregates there
            "per_replica",
            Json::obj(replica_sections.iter().map(|(n, j)| (*n, j.clone())).collect()),
        ),
        (
            "overlap_measured",
            Json::obj(
                overlaps
                    .iter()
                    .map(|(name, ov)| (*name, ov.to_json()))
                    .collect::<Vec<_>>(),
            ),
        ),
        (
            "traffic",
            Json::Str(traffic.map(|p| p.name()).unwrap_or("closed-loop").to_string()),
        ),
        (
            "digests",
            Json::obj(
                digests
                    .iter()
                    .map(|(name, d)| (*name, Json::Str(format!("{d:016x}"))))
                    .collect::<Vec<_>>(),
            ),
        ),
        ("baseline", base.to_json()),
        ("simple", simple.to_json()),
        // process-global decision-plane counters (steals, respawns, COW
        // forks, evictions, requeues, …) — DESIGN.md §14
        ("counters", simple_serve::trace::metrics::counters_json()),
    ]);
    let path = simple_serve::harness::default_results_dir().join("serve_e2e.json");
    simple_serve::util::json::write_json_file(&path, &out)?;
    println!("wrote {}", path.display());
    if let Some(p) = &trace_out {
        simple_serve::trace::export::write_chrome(p)?;
        println!("wrote trace capture {}", p.display());
    }
    if let Some(p) = args.get("metrics_out") {
        let path = std::path::PathBuf::from(p);
        simple_serve::trace::metrics::write_exposition(&path)?;
        println!("wrote metrics exposition {}", path.display());
    }
    Ok(())
}
