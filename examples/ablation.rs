//! §7.4 ablation (Figure 10): per-sampler decision throughput of the
//! four designs — naive CPU port → sequence-parallel → offloading
//! (column-wise + truncation-first) → SHVS — measured on this host.
//!
//! Run: `cargo run --release --example ablation [-- --quick]`

use simple_serve::harness::{micro, Effort};
use simple_serve::util::argparse::{Args, OptSpec};

fn main() -> simple_serve::Result<()> {
    let args = Args::parse_env(&[OptSpec::flag("quick", "fast run")], false)?;
    let effort = if args.flag("quick") { Effort::Quick } else { Effort::Full };
    let report = micro::fig10(effort);
    println!("{}", report.markdown);
    report.write(&simple_serve::harness::default_results_dir())?;
    Ok(())
}
