//! §7.6 exactness (Figure 13): cumulative mean TVD between the
//! SHVS-induced next-token distribution and the baseline sampler's —
//! theory says zero (Eq. 9); finite precision leaves a sub-1% residue.
//!
//! Run: `cargo run --release --example exactness [-- --quick]`

use simple_serve::harness::{exactness, Effort};
use simple_serve::util::argparse::{Args, OptSpec};

fn main() -> simple_serve::Result<()> {
    let args = Args::parse_env(&[OptSpec::flag("quick", "fast run")], false)?;
    let effort = if args.flag("quick") { Effort::Quick } else { Effort::Full };
    let report = exactness::fig13(effort);
    println!("{}", report.markdown);
    report.write(&simple_serve::harness::default_results_dir())?;
    Ok(())
}
