//! §5.4/§7.5 hot-vocab sizing (Figures 11 & 12): fit the affine hot-path
//! cost, measure the hit-ratio curve, compose F(H), and compare the
//! predicted H* with the measured throughput peak.
//!
//! Run: `cargo run --release --example sizing [-- --quick]`

use simple_serve::harness::{micro, Effort};
use simple_serve::util::argparse::{Args, OptSpec};

fn main() -> simple_serve::Result<()> {
    let args = Args::parse_env(&[OptSpec::flag("quick", "fast run")], false)?;
    let effort = if args.flag("quick") { Effort::Quick } else { Effort::Full };
    let dir = simple_serve::harness::default_results_dir();
    for report in [micro::fig11(effort), micro::fig12(effort)] {
        println!("{}", report.markdown);
        report.write(&dir)?;
    }
    Ok(())
}
