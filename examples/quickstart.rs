//! Quickstart: load the AOT micro model, serve a handful of text prompts
//! through the full stack (PJRT forward → shared logits view →
//! sequence-parallel SHVS samplers → commit), and print the generations.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

// Config structs are built by `default()` + field assignment (sweep-driver
// idiom); see the identical crate-level allow in lib.rs.
#![allow(clippy::field_reassign_with_default)]

use simple_serve::config::{DecisionVariant, EngineConfig};
use simple_serve::decision::{HotVocab, SamplingParams};
use simple_serve::engine::{tokenizer, PjrtEngine, Request};
use simple_serve::runtime::{default_artifacts_dir, Manifest, ModelRuntime};

fn main() -> simple_serve::Result<()> {
    let manifest = Manifest::load(&default_artifacts_dir())
        .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts` first"))?;
    let rt = ModelRuntime::load(&manifest, "micro-test")?;
    let vocab = rt.vocab();

    // Decision plane: SHVS with a trace-built hot set, 2 samplers.
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Shvs;
    cfg.sampler.num_samplers = 2;
    // The AOT model's next-token distribution is Zipf over ascending ids
    // (lm_bias construction), so the offline-profiled hot set is the low-id
    // head — exactly what trace profiling would find.
    let hot = HotVocab::new((0..(vocab / 8) as u32).collect(), vocab).into_arc();
    let mut engine = PjrtEngine::new(rt, &cfg, Some(hot));

    let prompts = [
        "The decision plane",
        "Sampling is",
        "Disaggregate",
        "Hot vocab",
    ];
    for (i, text) in prompts.iter().enumerate() {
        let mut req = Request::new(i as u64, tokenizer::encode(text), 12);
        req.params = SamplingParams {
            seed: i as u64,
            ..SamplingParams::production_default()
        };
        engine.submit(req);
    }

    let summary = engine.run_until_idle()?;
    let mut finished = engine.take_finished();
    finished.sort_by_key(|s| s.request.id);
    println!("— generations (tiny random-weight model, ids shown as ⟨id⟩) —");
    for seq in &finished {
        println!(
            "  {:?} -> {:?}",
            tokenizer::decode(&seq.request.prompt),
            tokenizer::decode(&seq.output)
        );
    }
    println!(
        "\n{} tokens in {:.2}s ({:.0} tok/s), TPOT p50 {:.2} ms",
        summary.tokens,
        summary.duration,
        summary.throughput,
        summary.tpot.p50 * 1e3
    );
    let (_, stats) = engine.shutdown();
    let decisions: u64 = stats.iter().map(|s| s.decisions).sum();
    let fast: u64 = stats.iter().map(|s| s.fast_path_hits).sum();
    println!(
        "decision plane: {decisions} decisions across {} samplers, {:.0}% fast path",
        stats.len(),
        fast as f64 / decisions.max(1) as f64 * 100.0
    );
    Ok(())
}
